//! The distributed inference engine: TP/PP/hybrid worker groups driven by a
//! coordinator, with every inter-worker byte flowing through the traced
//! collective library.
//!
//! Execution is iteration-level: [`Engine::session`] opens a [`Session`]
//! whose [`Session::step`] runs one prefill-or-decode iteration over the
//! active batch (continuous batching), streams per-sequence
//! [`TokenEvent`]s, and tags every traced collective with the step and
//! batch that issued it. [`Engine::generate`] is a thin single-sequence
//! wrapper over the session (a batch of one — byte-identical to the
//! paper's single-request methodology).
//!
//! Two modes share the identical control path (DESIGN.md §5):
//! - **numeric** — the tiny AOT model, real PJRT compute on every worker;
//!   used by the end-to-end example and the cross-layout equivalence
//!   tests; its fixed-shape executables hold single-sequence KV state, so
//!   sessions serve one sequence at a time;
//! - **structural** — paper-scale architectures with no-op compute; the
//!   communication stream (the paper's object of study) is unchanged,
//!   which is what the table/figure benches trace — and the mode that
//!   supports batched decode.

pub mod backend;
pub mod fused;
pub mod kv;
pub mod session;
pub mod worker;

pub use session::{PromptTokens, SequenceInput, Session, StepKind, StepOutcome, TokenEvent};

use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use std::sync::mpsc::{channel, Receiver, Sender};

use crate::analysis::ParallelLayout;
use crate::comm::{CommWorld, TraceSink};
use crate::model::ModelArch;
use crate::runtime::ArtifactStore;
use crate::simtime::CostModel;
use crate::Result;

use backend::{ComputeBackend, PjrtBackend, StructuralBackend};
use worker::{StepOutput, WorkerCmd, WorkerCtx};

/// Compute mode of the engine.
#[derive(Debug, Clone)]
pub enum EngineMode {
    /// Execute the tiny AOT model via PJRT on every worker.
    Numeric(ArtifactStore),
    /// No-op compute at paper scale; collective stream only.
    Structural,
}

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub arch: ModelArch,
    pub layout: ParallelLayout,
    pub mode: EngineMode,
    /// Element width recorded in traces (2 = BF16 like the paper's runs;
    /// numeric mode serves f32 and records 4).
    pub trace_dtype_bytes: usize,
    /// Prices traced collectives at record time and (on structural
    /// engines) drives the session's model-time clock. `None` disables
    /// pricing entirely.
    pub pricing: Option<CostModel>,
    /// Sarathi-style chunked-prefill budget: a prompt (suffix) longer
    /// than this many tokens prefills in budget-sized chunks interleaved
    /// with decode iterations of the running batch (mixed batches).
    /// `None` — the default — takes the unchunked one-shot prefill code
    /// path on every request, bitwise. Structural engines only.
    pub chunk_tokens: Option<usize>,
}

impl EngineConfig {
    /// Structural engine at paper scale (BF16 trace accounting), priced
    /// against the paper's 4-GPU-node topology with just enough nodes.
    pub fn structural(arch: ModelArch, layout: ParallelLayout) -> Self {
        let pricing = Some(CostModel::on_cardinal(arch.clone(), layout));
        Self {
            arch,
            layout,
            mode: EngineMode::Structural,
            trace_dtype_bytes: 2,
            pricing,
            chunk_tokens: None,
        }
    }

    /// Numeric engine over built artifacts (f32 tiny model). Wall clocks
    /// are the real latency here; no pricing is attached by default.
    pub fn numeric(store: ArtifactStore, layout: ParallelLayout) -> Self {
        Self {
            arch: ModelArch::tiny(),
            layout,
            mode: EngineMode::Numeric(store),
            trace_dtype_bytes: 4,
            pricing: None,
            chunk_tokens: None,
        }
    }

    /// Set the chunked-prefill budget (`None` keeps the one-shot path).
    pub fn with_chunk_tokens(mut self, chunk_tokens: Option<usize>) -> Self {
        self.chunk_tokens = chunk_tokens;
        self
    }

    /// Replace the pricing cost model (e.g. a plan's custom topology or
    /// calibration).
    pub fn with_pricing(mut self, pricing: CostModel) -> Self {
        self.pricing = Some(pricing);
        self
    }
}

/// Result of one generation request.
#[derive(Debug, Clone)]
pub struct GenerationResult {
    /// Generated token ids (length = requested decode length).
    pub tokens: Vec<i32>,
    /// Time to first token (prefill + first sample).
    pub ttft: Duration,
    /// Mean time per output token after the first.
    pub tpot: Duration,
    /// Total request latency.
    pub e2e: Duration,
    /// Per-decode-step latencies.
    pub step_latencies: Vec<Duration>,
}

/// The engine: owns worker threads for the lifetime of the object.
pub struct Engine {
    cfg: EngineConfig,
    cmd_txs: Vec<Sender<WorkerCmd>>,
    out_rx: Receiver<Result<StepOutput>>,
    sink: std::sync::Arc<TraceSink>,
    joins: Vec<JoinHandle<()>>,
    /// Iterations issued over this engine's lifetime — the step-tag
    /// counter continues across sessions so per-step trace aggregation
    /// (`TraceSummary::step_comm_s`) never conflates two sessions'
    /// iterations into one bucket.
    steps_issued: u64,
    /// Collective seconds the pricing model hid behind compute over this
    /// engine's lifetime (the `CollectiveTuning` overlap factor). Exactly
    /// 0.0 at the default tuning; accumulates across sessions like
    /// `steps_issued` so serving summaries can report it after the
    /// session is gone.
    hidden_comm_s: f64,
}

impl Engine {
    /// Build worker topology and spawn worker threads.
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        let layout = cfg.layout;
        let (t, p) = (layout.tp, layout.pp);
        if !cfg.arch.supports_tp(t) {
            anyhow::bail!("{} does not divide across tp={t}", cfg.arch.name);
        }
        if !cfg.arch.supports_pp(p) {
            anyhow::bail!("{} does not divide across pp={p}", cfg.arch.name);
        }
        if let EngineMode::Numeric(store) = &cfg.mode {
            if !store.supports_tp(t) {
                anyhow::bail!("artifacts not built for tp={t}");
            }
            if cfg.chunk_tokens.is_some() {
                anyhow::bail!(
                    "chunked prefill needs a structural engine: numeric PJRT \
                     executables are fixed-shape and cannot split a prompt"
                );
            }
        }
        if cfg.chunk_tokens == Some(0) {
            anyhow::bail!("chunked prefill budget must be >= 1 token");
        }

        let world = layout.world_size();
        let sink = TraceSink::new();
        if let Some(pricing) = &cfg.pricing {
            // A pricer for a different layout or architecture would
            // silently misprice every record and model-time clock (wrong
            // group stages, wrong weight/KV streams) — reject the
            // mismatch here instead.
            if pricing.placement.layout != layout {
                anyhow::bail!(
                    "pricing cost model is for layout {} but the engine runs {}",
                    pricing.placement.layout.label(),
                    layout.label()
                );
            }
            if pricing.arch != cfg.arch {
                anyhow::bail!(
                    "pricing cost model is for {} but the engine serves {}",
                    pricing.arch.name,
                    cfg.arch.name
                );
            }
            sink.set_pricer(pricing.clone());
        }
        let comm = CommWorld::new(world, cfg.trace_dtype_bytes, sink.clone());
        let (out_tx, out_rx) = channel();

        // Stage layer ranges.
        let mut ranges = Vec::with_capacity(p);
        let mut start = 0usize;
        for s in 0..p {
            let n = cfg.arch.stage_layers(p, s);
            ranges.push(start..start + n);
            start += n;
        }

        // TP groups per stage (global rank = s*t + tp_rank).
        let mut stage_groups: Vec<Vec<crate::comm::GroupHandle>> = Vec::with_capacity(p);
        for s in 0..p {
            let ranks: Vec<usize> = (0..t).map(|r| s * t + r).collect();
            stage_groups.push(comm.create_group(&ranks));
        }

        let mut cmd_txs = Vec::with_capacity(world);
        let mut joins = Vec::with_capacity(world);
        for s in 0..p {
            for r in 0..t {
                let global_rank = s * t + r;
                let (cmd_tx, cmd_rx) = channel();
                cmd_txs.push(cmd_tx);
                let prev = (s > 0).then(|| comm.receiver((s - 1) * t + r, global_rank));
                let next = (s + 1 < p).then(|| comm.sender(global_rank, (s + 1) * t + r));
                let is_driver = s == p - 1 && r == 0;
                let ctx = WorkerCtx {
                    global_rank,
                    pp_stage: s,
                    tp_rank: r,
                    tp: t,
                    pp: p,
                    hidden: cfg.arch.hidden,
                    layer_range: ranges[s].clone(),
                    tp_group: stage_groups[s][r].clone(),
                    prev,
                    next,
                    cmd_rx,
                    out_tx: is_driver.then(|| out_tx.clone()),
                };
                let mode = cfg.mode.clone();
                let arch = cfg.arch.clone();
                let join = std::thread::Builder::new()
                    .name(format!("worker-{global_rank}"))
                    .spawn(move || {
                        let backend: Box<dyn ComputeBackend> = match &mode {
                            EngineMode::Structural => {
                                Box::new(StructuralBackend::new(&arch, t))
                            }
                            EngineMode::Numeric(store) => {
                                match PjrtBackend::new_on_thread(store, t, r) {
                                    Ok(b) => Box::new(b),
                                    Err(e) => panic!("worker {global_rank} backend: {e:?}"),
                                }
                            }
                        };
                        ctx.run(backend);
                    })
                    .map_err(|e| anyhow::anyhow!("spawn: {e}"))?;
                joins.push(join);
            }
        }

        Ok(Self { cfg, cmd_txs, out_rx, sink, joins, steps_issued: 0, hidden_comm_s: 0.0 })
    }

    /// The shared communication trace.
    pub fn trace(&self) -> std::sync::Arc<TraceSink> {
        self.sink.clone()
    }

    /// Collective seconds hidden behind compute by the pricing model's
    /// overlap tuning over this engine's lifetime (0.0 when untuned).
    pub fn hidden_comm_s(&self) -> f64 {
        self.hidden_comm_s
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    fn broadcast(&self, cmd: WorkerCmd) -> Result<()> {
        for tx in &self.cmd_txs {
            tx.send(cmd.clone()).map_err(|_| anyhow::anyhow!("worker hung up"))?;
        }
        Ok(())
    }

    /// Maximum time to wait for a step result before declaring the worker
    /// group wedged (a worker panic inside a collective would otherwise
    /// deadlock its peers forever).
    const STEP_TIMEOUT: Duration = Duration::from_secs(120);

    fn recv_logits(&self) -> Result<Vec<f32>> {
        match self.out_rx.recv_timeout(Self::STEP_TIMEOUT) {
            Ok(Ok(out)) => Ok(out.logits),
            Ok(Err(e)) => Err(e),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Err(anyhow::anyhow!(
                "no step result within {:?} — a worker likely failed mid-collective",
                Self::STEP_TIMEOUT
            )),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                Err(anyhow::anyhow!("driver worker hung up"))
            }
        }
    }

    /// Run one throwaway request to trigger lazy first-execution setup in
    /// every worker's executables (PJRT finalizes on first run), excluded
    /// from the trace. Serving paths call this once so the first real
    /// request's TTFT is not inflated — the same warmup vLLM performs.
    pub fn warmup(&mut self) -> Result<()> {
        let prompt_len = match &self.cfg.mode {
            EngineMode::Numeric(store) => store.meta.prefill_len,
            EngineMode::Structural => 8,
        };
        self.sink.set_enabled(false);
        let result = self.generate(&vec![0i32; prompt_len], 2);
        self.sink.set_enabled(true);
        self.sink.clear();
        result.map(|_| ())
    }

    /// Whether this engine can decode several sequences in one iteration
    /// (continuous batching). Structural backends batch; the numeric PJRT
    /// executables are fixed-shape with single-sequence KV state.
    pub fn supports_batched_decode(&self) -> bool {
        matches!(self.cfg.mode, EngineMode::Structural)
    }

    /// The cost model pricing this engine's traces (and, on structural
    /// engines, its sessions' model-time clock), if any.
    pub fn cost_model(&self) -> Option<&CostModel> {
        self.cfg.pricing.as_ref()
    }

    /// Open an iteration-level [`Session`] over this engine: admit
    /// sequences, then drive [`Session::step`] — one prefill-or-decode
    /// iteration per call, streaming [`TokenEvent`]s.
    pub fn session(&mut self) -> Session<'_> {
        Session::new(self)
    }

    /// Serve one request: prefill on `prompt`, then greedy-decode
    /// `decode_len` tokens total (first token comes out of prefill —
    /// paper's S_d counting).
    ///
    /// This is a thin single-sequence wrapper over [`Self::session`]; a
    /// batch of one issues the identical command and collective stream the
    /// pre-session engine did, so traces are unchanged.
    pub fn generate(&mut self, prompt: &[i32], decode_len: usize) -> Result<GenerationResult> {
        assert!(decode_len >= 1);
        let start = Instant::now();
        let mut session = Session::new(self);
        session.admit(SequenceInput {
            id: 0,
            prompt: prompt.to_vec().into(),
            start: 0,
            max_new_tokens: decode_len,
        })?;
        let mut tokens = Vec::with_capacity(decode_len);
        let mut ttft = Duration::ZERO;
        let mut step_latencies = Vec::with_capacity(decode_len.saturating_sub(1));
        while !session.is_idle() {
            let out = session.step()?;
            match out.kind {
                // A chunked prompt prefills over several iterations; the
                // last one emits the first token, so TTFT lands there.
                StepKind::Prefill => ttft = start.elapsed(),
                StepKind::Decode | StepKind::Mixed => step_latencies.push(out.latency),
                StepKind::Idle => break,
            }
            for e in out.events {
                tokens.push(e.token);
            }
        }
        let e2e = start.elapsed();
        let tpot = if step_latencies.is_empty() {
            Duration::ZERO
        } else {
            step_latencies.iter().sum::<Duration>() / step_latencies.len() as u32
        };
        Ok(GenerationResult { tokens, ttft, tpot, e2e, step_latencies })
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.broadcast(WorkerCmd::Shutdown);
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{InferenceShape, OpCountModel};
    use crate::comm::{CollectiveKind, Stage};

    fn structural_engine(arch: ModelArch, tp: usize, pp: usize) -> Engine {
        Engine::new(EngineConfig::structural(arch, ParallelLayout::new(tp, pp))).unwrap()
    }

    #[test]
    fn structural_tp2_trace_matches_analytical_counts() {
        let arch = ModelArch::tiny();
        let mut e = structural_engine(arch.clone(), 2, 1);
        let prompt = vec![0i32; 16];
        let r = e.generate(&prompt, 8).unwrap();
        assert_eq!(r.tokens.len(), 8);

        let summary = e.trace().summary();
        let model = OpCountModel::new(
            arch,
            ParallelLayout::new(2, 1),
            InferenceShape::new(16, 8, 2),
        );
        for stage in [Stage::Prefill, Stage::Decode] {
            let predicted = model.predict_paper_view(stage);
            for op in [CollectiveKind::AllReduce, CollectiveKind::Gather] {
                assert_eq!(
                    summary.paper_view(op, stage).count,
                    predicted.count(op),
                    "{op:?} {stage:?}"
                );
            }
        }
    }

    #[test]
    fn structural_pp_trace_matches_table5_pattern() {
        let arch = ModelArch::tiny(); // 4 layers
        let mut e = structural_engine(arch.clone(), 1, 2);
        let r = e.generate(&[0i32; 16], 8).unwrap();
        assert_eq!(r.tokens.len(), 8);
        let s = e.trace().summary();
        // (p-1) * 2 tensors per step; prefill 1 step, decode 7 steps.
        assert_eq!(s.global_count(CollectiveKind::Send, Stage::Prefill), 2);
        assert_eq!(s.global_count(CollectiveKind::Recv, Stage::Prefill), 2);
        assert_eq!(s.global_count(CollectiveKind::Send, Stage::Decode), 14);
        assert_eq!(s.global_count(CollectiveKind::Recv, Stage::Decode), 14);
        // No collectives at t=1.
        assert_eq!(s.global_count(CollectiveKind::AllReduce, Stage::Decode), 0);
    }

    #[test]
    fn structural_hybrid_trace_matches_table6_pattern() {
        let arch = ModelArch::tiny(); // L=4 -> per stage 2L/p = 4, +1 embed
        let mut e = structural_engine(arch.clone(), 2, 2);
        e.generate(&[0i32; 16], 8).unwrap();
        let s = e.trace().summary();
        // Stage-0 ranks: 2*2+1 = 5 AllReduce prefill.
        assert_eq!(s.paper_view(CollectiveKind::AllReduce, Stage::Prefill).count, 5);
        assert_eq!(s.paper_view(CollectiveKind::AllGather, Stage::Prefill).count, 2);
        assert_eq!(s.paper_view(CollectiveKind::Gather, Stage::Prefill).count, 1);
        // Send shape is the TP-local slice.
        let shapes = s.shapes(CollectiveKind::Send, Stage::Prefill);
        assert_eq!(shapes, vec![vec![16, arch.hidden / 2]]);
        // Decode: x7 steps.
        assert_eq!(s.paper_view(CollectiveKind::AllReduce, Stage::Decode).count, 35);
        assert_eq!(s.paper_view(CollectiveKind::AllGather, Stage::Decode).count, 14);
    }

    #[test]
    fn engine_rejects_unsupported_layouts() {
        let arch = ModelArch::tiny();
        assert!(Engine::new(EngineConfig::structural(arch.clone(), ParallelLayout::new(3, 1)))
            .is_err());
        assert!(Engine::new(EngineConfig::structural(arch, ParallelLayout::new(1, 8))).is_err());
    }

    #[test]
    fn engine_rejects_pricing_for_a_different_layout_or_arch() {
        let arch = ModelArch::tiny();
        let cfg = EngineConfig::structural(arch.clone(), ParallelLayout::new(2, 1))
            .with_pricing(CostModel::on_cardinal(arch.clone(), ParallelLayout::new(4, 1)));
        let err = Engine::new(cfg).unwrap_err();
        assert!(err.to_string().contains("pricing cost model"), "{err}");
        let cfg = EngineConfig::structural(arch, ParallelLayout::new(2, 1)).with_pricing(
            CostModel::on_cardinal(ModelArch::llama32_3b(), ParallelLayout::new(2, 1)),
        );
        let err = Engine::new(cfg).unwrap_err();
        assert!(err.to_string().contains("engine serves"), "{err}");
    }

    #[test]
    fn consecutive_requests_are_isolated() {
        let mut e = structural_engine(ModelArch::tiny(), 2, 1);
        e.generate(&[0i32; 8], 4).unwrap();
        let first = e.trace().len();
        e.trace().clear();
        e.generate(&[0i32; 8], 4).unwrap();
        assert_eq!(e.trace().len(), first, "same request -> same trace size");
    }
}
