//! Virtual-clock discrete-cost engine — one α–β/compute pricing core.
//!
//! The paper's deliverable is a *predictive analytical model*: every
//! collective and compute phase is priced, and the prices explain the
//! TP/PP/hybrid latency trade-offs. This module is that model as a
//! subsystem the whole stack shares:
//!
//! - [`algebra`] — the ring/hierarchical collective formula set (byte
//!   factors, step counts). Trace accounting
//!   ([`crate::comm::CollectiveKind::correction_factor`]), the Eq. 1–7
//!   volume closed forms ([`crate::analysis::VolumeModel`]) and the α–β
//!   time model ([`crate::cluster::NetModel`]) all delegate here.
//! - [`CostModel`] — (architecture, placement, calibration) pricing:
//!   closed-form phase breakdowns (what [`crate::perfmodel::SloSimulator`]
//!   reports), per-iteration timeline posting (what structural serving
//!   reports SLOs in), and per-record pricing (the modeled seconds on
//!   every traced [`crate::comm::CommRecord`]).
//! - [`Timeline`] — per-rank virtual clocks advanced by posted events
//!   (compute, collective, P2P, barrier; plus an overlap-window
//!   primitive for overlap-aware models — the eager-mode serving path
//!   does not post it).
//!
//! **Model time vs wall time.** Structural engines execute no real GPU
//! work, so host timestamps measure thread scheduling, not serving. Every
//! layer that reports latency therefore carries both: wall-clock (what the
//! host actually took — the meaningful number for numeric PJRT serving)
//! and model time (what the calibrated H100/NVLink/IB testbed *would*
//! take — the meaningful number for structural serving, and deterministic
//! for a fixed workload and seed).

pub mod algebra;
mod cost;
mod timeline;

pub use cost::{CostModel, PhaseBreakdown};
pub use timeline::Timeline;
