//! Shared collective algebra — the one source of truth for ring-algorithm
//! byte factors and step counts.
//!
//! Every layer that prices or accounts a collective derives from these
//! functions: [`crate::comm::CollectiveKind::correction_factor`] (trace
//! volume accounting), [`crate::analysis::VolumeModel`] (Eq. 1–7 closed
//! forms) and [`crate::cluster::NetModel`] (α–β time costs) all delegate
//! here, so the `2(d−1)/d` of a traced AllReduce, of the analytical volume
//! model, and of the priced α–β transfer term can never drift apart.
//!
//! Conventions (NCCL ring algorithms, paper §V.B / [16]):
//! - AllReduce over `d` workers: `2(d−1)` steps, `2(d−1)/d · n` bytes/GPU.
//! - AllGather / ReduceScatter / AllToAll: `(d−1)` steps, `(d−1)/d · n`.
//! - Gather / Send / Recv: one launch, bytes uncorrected.

/// AllReduce byte factor `2(d−1)/d` (ring algorithm bytes per GPU).
pub fn allreduce_factor(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        2.0 * (d as f64 - 1.0) / d as f64
    }
}

/// AllGather / ReduceScatter / AllToAll byte factor `(d−1)/d`.
pub fn allgather_factor(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        (d as f64 - 1.0) / d as f64
    }
}

/// AllReduce ring step count `2(d−1)` — the α (launch latency) multiplier.
pub fn allreduce_steps(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        2.0 * (d as f64 - 1.0)
    }
}

/// AllGather / ReduceScatter / AllToAll ring step count `(d−1)`.
pub fn allgather_steps(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        d as f64 - 1.0
    }
}

/// Quantized AllReduce launch count: the Flash Communication decomposition
/// (all-to-all + all-gather, arXiv:2412.04964 §3) replaces the ring's
/// `2(d−1)` serialized launches with two fused kernels regardless of `d`.
pub fn quantized_allreduce_steps(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else {
        2.0
    }
}

/// Two-step all-gather launch count: stage the quantized payload through a
/// per-node leader, so `d > 2` groups pay two launches instead of the
/// ring's `d−1` (a two-member group still needs only its single exchange).
pub fn two_step_allgather_steps(d: usize) -> f64 {
    if d <= 1 {
        0.0
    } else if d == 2 {
        1.0
    } else {
        2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_factors() {
        assert_eq!(allreduce_factor(1), 0.0);
        assert!((allreduce_factor(2) - 1.0).abs() < 1e-12);
        assert!((allreduce_factor(4) - 1.5).abs() < 1e-12);
        assert_eq!(allgather_factor(1), 0.0);
        assert!((allgather_factor(4) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn ring_steps() {
        assert_eq!(allreduce_steps(1), 0.0);
        assert_eq!(allreduce_steps(4), 6.0);
        assert_eq!(allgather_steps(4), 3.0);
    }

    #[test]
    fn factors_are_monotone_in_group_size() {
        for d in 2..32usize {
            assert!(allreduce_factor(d + 1) > allreduce_factor(d));
            assert!(allgather_factor(d + 1) > allgather_factor(d));
            assert!(allreduce_steps(d + 1) > allreduce_steps(d));
        }
    }

    #[test]
    fn quantized_variants_never_exceed_the_ring_launch_counts() {
        assert_eq!(quantized_allreduce_steps(1), 0.0);
        assert_eq!(two_step_allgather_steps(1), 0.0);
        assert_eq!(quantized_allreduce_steps(2), 2.0);
        assert_eq!(two_step_allgather_steps(2), 1.0);
        assert_eq!(two_step_allgather_steps(4), 2.0);
        for d in 2..64usize {
            assert!(quantized_allreduce_steps(d) <= allreduce_steps(d));
            assert!(two_step_allgather_steps(d) <= allgather_steps(d));
        }
    }
}
