//! The cost model — one α–β/compute pricing core.
//!
//! [`CostModel`] bundles the three calibrated components (H100 roofline
//! [`crate::perfmodel::ComputeModel`], α–β [`crate::cluster::NetModel`],
//! fitted framework overheads [`Calibration`]) over a concrete
//! [`Placement`], and prices everything the stack wants timed:
//!
//! - **closed forms** — [`CostModel::prefill_breakdown`] /
//!   [`CostModel::decode_step_breakdown`] are the per-phase decompositions
//!   the SLO simulator reports (Figs. 1, 8–10); the simulator is a thin
//!   view over them.
//! - **timeline posting** — [`CostModel::post_prefill`] /
//!   [`CostModel::post_decode`] replay one engine iteration onto a
//!   [`Timeline`]: per-stage compute, TP collectives, boundary P2P and the
//!   coordinator round-trip, advancing per-rank virtual clocks. This is
//!   how structural serving gets model-time SLOs under continuous
//!   batching (the decode forms take the *actual* per-sequence KV lengths
//!   of the batch, not the single-request midpoint).
//! - **record pricing** — [`CostModel::price_record`] prices one traced
//!   [`CommRecord`] (the per-op modeled seconds the trace summary
//!   aggregates per step and batch).

use crate::analysis::{InferenceShape, ParallelLayout};
use crate::cluster::{CollectiveCost, Placement, Topology};
use crate::comm::{CollectiveKind, CommRecord, Stage, TraceSummary};
use crate::model::ModelArch;
use crate::perfmodel::Calibration;

use super::timeline::Timeline;

/// Time decomposition of one phase (seconds).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub compute_s: f64,
    pub comm_s: f64,
    pub overhead_s: f64,
}

impl PhaseBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_s + self.comm_s + self.overhead_s
    }

    /// Communication fraction of total phase time (Fig. 1 y-axis).
    pub fn comm_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0.0 { 0.0 } else { self.comm_s / t }
    }
}

/// The shared pricing core: (architecture, placement, calibration).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub arch: ModelArch,
    pub placement: Placement,
    pub cal: Calibration,
    /// Per-stage node-spanning flags, derived from `placement` at
    /// construction — the record-pricing hot path asks once per traced
    /// collective, so this is cached instead of rebuilding the TP group.
    stage_crosses: Vec<bool>,
}

impl CostModel {
    pub fn new(arch: ModelArch, placement: Placement, cal: Calibration) -> Self {
        let stage_crosses = (0..placement.layout.pp)
            .map(|s| placement.tp_group_crosses_nodes(s))
            .collect();
        Self { arch, placement, cal, stage_crosses }
    }

    /// Place a layout on the paper's 4-GPU-node topology with just enough
    /// nodes (the default every structural engine prices against).
    pub fn on_cardinal(arch: ModelArch, layout: ParallelLayout) -> Self {
        let nodes = layout.world_size().div_ceil(4).max(1);
        let placement = Placement::new(Topology::cardinal(nodes), layout)
            .expect("just-enough cardinal topology always fits");
        Self::new(arch, placement, Calibration::default())
    }

    fn layout(&self) -> ParallelLayout {
        self.placement.layout
    }

    /// Per-step communication time of stage `s`: `window`-token TP
    /// collectives, `sampled`-token logits gather on the last stage, and
    /// boundary p2p wire time (attributed to the sending stage).
    ///
    /// AllReduce/AllGather payloads honor the calibration's
    /// [`crate::cluster::CollectiveTuning`]: a quantized wire prices the
    /// variant formulas plus one quant/dequant HBM pass-pair per launch
    /// ([`crate::perfmodel::ComputeModel::quant_dequant_time`]). The
    /// default tuning never touches the variant paths, so it is bitwise
    /// the untuned model.
    fn stage_comm(&self, s: usize, window: usize, sampled: usize) -> f64 {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let b = self.cal.compute.dtype_bytes;
        let h = self.arch.hidden as f64;
        let msg = window as f64 * h * b;
        let crosses = self.stage_crosses[s];
        let net = &self.cal.net;
        let tuning = self.cal.tuning;
        let mut time = 0.0;

        if t > 1 {
            let mut ars = 2 * self.arch.stage_layers(p, s);
            if s == 0 {
                ars += 1; // vocab-parallel embedding
            }
            let mut ar = net.allreduce_tuned(msg, t, crosses, tuning).total();
            if tuning.quantizes() {
                ar += self.cal.compute.quant_dequant_time(msg);
            }
            time += ars as f64 * ar;
            if p > 1 && s > 0 {
                let mut ag = net.allgather_tuned(msg, t, crosses, tuning).total();
                if tuning.quantizes() {
                    ag += self.cal.compute.quant_dequant_time(msg);
                }
                time += 2.0 * ag;
            }
            if s == p - 1 {
                // Logits gather of v/t slices, once per sampled token (one
                // for prefill, the active batch for a decode iteration).
                let slice = sampled as f64 * (self.arch.vocab / t) as f64 * b;
                time += net.gather(slice, t, crosses).total();
            }
        }
        if p > 1 && s < p - 1 {
            let cross = self.placement.pp_boundary_crosses_nodes(s);
            let slice = msg / t as f64;
            time += 2.0 * net.p2p(slice, cross).total();
        }
        time
    }

    /// Framework overhead of one prefill iteration (vLLM intake fit +
    /// serialized pipeline-stage spin-up).
    fn prefill_overhead(&self) -> f64 {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let mut overhead = self.cal.ttft_framework_overhead(self.layout().world_size());
        overhead += (p - 1) as f64 * self.cal.pp_boundary_prefill_s * (t as f64).powf(
            if p > 1 { self.cal.handoff_tp_exp } else { 0.0 },
        );
        overhead
    }

    /// Framework handoff overhead (per step) for pipeline boundaries,
    /// including the sampled-token return hop to stage 0.
    fn decode_handoff_overhead(&self) -> f64 {
        let p = self.layout().pp;
        if p <= 1 {
            return 0.0;
        }
        let t = self.layout().tp;
        let mut crossings = self.placement.internode_boundaries();
        // Return hop: last stage -> first stage.
        let last = self.placement.global_rank(p - 1, 0);
        let first = self.placement.global_rank(0, 0);
        if !self.placement.topology.same_node(last, first) {
            crossings += 1;
        }
        crossings as f64 * self.cal.internode_handoff(t)
    }

    /// Split a stage's serialized comm into (exposed, hidden) under the
    /// tuning's overlap factor: up to `overlap · compute` of collective
    /// time hides behind the stage's compute. The zero-overlap default
    /// returns `(comm, 0.0)` without touching the arithmetic — bitwise
    /// the untuned exposure.
    fn apply_overlap(&self, compute: f64, comm: f64) -> (f64, f64) {
        let ov = self.cal.tuning.overlap();
        if ov == 0.0 {
            return (comm, 0.0);
        }
        let hidden = (ov * compute).min(comm);
        (comm - hidden, hidden)
    }

    /// Roofline compute, *exposed* comm, and overlap-hidden comm of
    /// pipeline stage `s` during a prefill of `prompt_len` tokens — the
    /// one per-stage formula both the closed-form breakdown and the
    /// timeline posting consume. With one microbatch per iteration the
    /// per-stage overlap window is the per-iteration window.
    fn prefill_stage_cost(&self, s: usize, prompt_len: usize) -> (f64, f64, f64) {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let layers = self.arch.stage_layers(p, s);
        let compute = self.cal.compute.prefill_time(&self.arch, layers, prompt_len, t);
        let (exposed, hidden) = self.apply_overlap(compute, self.stage_comm(s, prompt_len, 1));
        (compute, exposed, hidden)
    }

    /// Framework overhead of one prefill *chunk* iteration: the first
    /// chunk pays the full prefill intake (the request enters the engine
    /// there); every later chunk is one more engine step.
    fn chunk_overhead(&self, start: usize) -> f64 {
        if start == 0 {
            self.prefill_overhead()
        } else {
            self.cal.step_overhead_s
        }
    }

    /// Per-stage costs of one chunked-prefill iteration: `len` chunk
    /// tokens starting at prompt offset `start`. Compute is the chunk's
    /// roofline time (GEMMs over the chunk, attention over the growing
    /// `start..start+len × context` window); collectives carry the
    /// chunk's `[len, h]` activation volume with one sampled token (the
    /// last-stage logits gather runs once per prefill command). Returns
    /// (compute, exposed comm, overlap-hidden comm).
    fn prefill_chunk_stage_cost(&self, s: usize, start: usize, len: usize) -> (f64, f64, f64) {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let layers = self.arch.stage_layers(p, s);
        let compute = self.cal.compute.prefill_chunk_time(&self.arch, layers, start, len, t);
        let (exposed, hidden) = self.apply_overlap(compute, self.stage_comm(s, len, 1));
        (compute, exposed, hidden)
    }

    /// Per-stage costs of one *mixed* iteration: a `len`-token prefill
    /// chunk at offset `start` fused with a decode step over `kv_lens`.
    /// Compute is chunk + batched decode; collectives are launched once
    /// over the fused `[len + B, h]` activation window and the logits
    /// gather samples `1 + B` tokens (the chunk's probe plus every decode
    /// victim). Returns (compute, exposed comm, overlap-hidden comm).
    fn mixed_stage_cost(
        &self,
        s: usize,
        start: usize,
        len: usize,
        kv_lens: &[usize],
    ) -> (f64, f64, f64) {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let batch = kv_lens.len();
        let layers = self.arch.stage_layers(p, s);
        let compute = self.cal.compute.prefill_chunk_time(&self.arch, layers, start, len, t)
            + self.cal.compute.decode_batch_time(&self.arch, layers, kv_lens, t);
        let (exposed, hidden) =
            self.apply_overlap(compute, self.stage_comm(s, len + batch, 1 + batch));
        (compute, exposed, hidden)
    }

    /// Closed-form breakdown of one chunked-prefill iteration: `len`
    /// tokens starting at offset `start` of the (uncached) prompt suffix,
    /// attending over everything before them. The first chunk pays the
    /// prefill intake overhead; later chunks pay one engine step each, so
    /// a multi-chunk split never underprices the one-shot prefill.
    pub fn prefill_chunk_breakdown(&self, start: usize, len: usize) -> PhaseBreakdown {
        assert!(len >= 1, "prefill chunk needs >= 1 token");
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..self.layout().pp {
            let (c, m, _hidden) = self.prefill_chunk_stage_cost(s, start, len);
            compute += c;
            comm += m;
        }
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: self.chunk_overhead(start) }
    }

    /// Closed-form breakdown of one mixed iteration (one prefill chunk +
    /// a decode step over the running batch), priced as a single fused
    /// launch: weights stream once, collectives carry the fused window,
    /// and the overhead is the chunk's plus the decode handoff — so the
    /// chunk owner's TTFT and every decode victim's TPOT stretch by the
    /// same honest iteration time.
    pub fn mixed_iteration(
        &self,
        chunk_start: usize,
        chunk_len: usize,
        kv_lens: &[usize],
    ) -> PhaseBreakdown {
        assert!(chunk_len >= 1, "mixed iteration needs a >= 1 token chunk");
        assert!(!kv_lens.is_empty(), "mixed iteration needs >= 1 decode sequence");
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..self.layout().pp {
            let (c, m, _hidden) = self.mixed_stage_cost(s, chunk_start, chunk_len, kv_lens);
            compute += c;
            comm += m;
        }
        let overhead = self.chunk_overhead(chunk_start) + self.decode_handoff_overhead();
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: overhead }
    }

    /// Per-stage costs of one decode iteration over `kv_lens` (weights
    /// stream once, KV per sequence, `[B, h]` collective payloads).
    /// Returns (compute, exposed comm, overlap-hidden comm).
    fn decode_stage_cost(&self, s: usize, kv_lens: &[usize]) -> (f64, f64, f64) {
        let (t, p) = (self.layout().tp, self.layout().pp);
        let batch = kv_lens.len();
        let layers = self.arch.stage_layers(p, s);
        let compute = self.cal.compute.decode_batch_time(&self.arch, layers, kv_lens, t);
        let (exposed, hidden) = self.apply_overlap(compute, self.stage_comm(s, batch, batch));
        (compute, exposed, hidden)
    }

    /// Prefill phase breakdown → TTFT (closed form; only
    /// `shape.prefill_len` matters).
    pub fn prefill_breakdown(&self, shape: InferenceShape) -> PhaseBreakdown {
        let sp = shape.prefill_len;
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..self.layout().pp {
            let (c, m, _hidden) = self.prefill_stage_cost(s, sp);
            compute += c;
            comm += m;
        }
        let overhead = self.prefill_overhead();
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: overhead }
    }

    /// Collective seconds a `prompt_len`-token prefill hides behind
    /// compute under the tuning's overlap factor (0.0 at the default).
    pub fn prefill_hidden_comm_s(&self, prompt_len: usize) -> f64 {
        (0..self.layout().pp).map(|s| self.prefill_stage_cost(s, prompt_len).2).sum()
    }

    /// Collective seconds one decode iteration over `kv_lens` hides behind
    /// compute under the tuning's overlap factor (0.0 at the default).
    pub fn decode_hidden_comm_s(&self, kv_lens: &[usize]) -> f64 {
        (0..self.layout().pp).map(|s| self.decode_stage_cost(s, kv_lens).2).sum()
    }

    /// Closed-form price (seconds) of a `prompt_len`-token prefill — the
    /// standalone TTFT that prompt would pay on this plan. The
    /// prefix-cache accounting hook: a request admitted with a
    /// `cached`-token prefix hint prefills only its suffix, and
    /// `prefill_price(full) - prefill_price(full - cached)` is the saved
    /// prefill seconds it is credited with (framework overhead cancels in
    /// the difference — the engine still runs one prefill iteration).
    pub fn prefill_price(&self, prompt_len: usize) -> f64 {
        let b = self.cal.compute.dtype_bytes as usize;
        self.prefill_breakdown(InferenceShape::new(prompt_len, 1, b)).total()
    }

    /// Corrected communication volume (bytes) of a `prompt_len`-token
    /// prefill phase on this layout — the Eq. 1–7 prefill-side terms plus
    /// its single logits gather (which cancels in saved-bytes
    /// differences, since a cached prefix never skips the gather).
    pub fn prefill_comm_bytes(&self, prompt_len: usize) -> f64 {
        let b = self.cal.compute.dtype_bytes as usize;
        crate::analysis::VolumeModel::new(self.arch.clone())
            .volume(self.layout(), InferenceShape::new(prompt_len, 1, b))
            .total()
    }

    /// One single-request decode step breakdown → TPOT (closed form, at
    /// the paper's mid-generation context length).
    pub fn decode_step_breakdown(&self, shape: InferenceShape) -> PhaseBreakdown {
        // Mid-generation context length for KV streaming cost.
        let kv_len = shape.prefill_len + shape.decode_len / 2;
        self.decode_iteration(&[kv_len])
    }

    /// One decode iteration over an active batch: `kv_lens[i]` is sequence
    /// `i`'s current context length. Weights stream once per iteration
    /// (shared by the batch); KV streams per sequence; collective payloads
    /// are `[B, h]`; the logits gather carries `B` sampled tokens; the
    /// per-step engine overhead is paid once. A batch of one at the
    /// mid-generation context is exactly [`Self::decode_step_breakdown`].
    pub fn decode_iteration(&self, kv_lens: &[usize]) -> PhaseBreakdown {
        assert!(!kv_lens.is_empty(), "decode iteration needs >= 1 sequence");
        let mut compute = 0.0;
        let mut comm = 0.0;
        for s in 0..self.layout().pp {
            let (c, m, _hidden) = self.decode_stage_cost(s, kv_lens);
            compute += c;
            comm += m;
        }
        let overhead = self.cal.step_overhead_s + self.decode_handoff_overhead();
        PhaseBreakdown { compute_s: compute, comm_s: comm, overhead_s: overhead }
    }

    /// Replay one prefill iteration onto the timeline (per-stage compute,
    /// TP collectives, boundary handoffs, coordinator round-trip).
    /// Returns the iteration's model-time duration plus the collective
    /// seconds the tuning's overlap factor hid behind compute (0.0 at the
    /// default).
    pub fn post_prefill(&self, tl: &mut Timeline, prompt_len: usize) -> (f64, f64) {
        self.post_iteration(
            tl,
            |s, cm| cm.prefill_stage_cost(s, prompt_len),
            self.prefill_overhead(),
        )
    }

    /// Replay one chunked-prefill iteration onto the timeline (the
    /// posting analogue of [`Self::prefill_chunk_breakdown`]). Returns
    /// (duration, overlap-hidden comm seconds).
    pub fn post_prefill_chunk(&self, tl: &mut Timeline, start: usize, len: usize) -> (f64, f64) {
        assert!(len >= 1, "prefill chunk needs >= 1 token");
        self.post_iteration(
            tl,
            |s, cm| cm.prefill_chunk_stage_cost(s, start, len),
            self.chunk_overhead(start),
        )
    }

    /// Replay one mixed iteration (prefill chunk + decode batch) onto the
    /// timeline as a single fused launch (the posting analogue of
    /// [`Self::mixed_iteration`]). Returns (duration, overlap-hidden comm
    /// seconds).
    pub fn post_mixed(
        &self,
        tl: &mut Timeline,
        chunk_start: usize,
        chunk_len: usize,
        kv_lens: &[usize],
    ) -> (f64, f64) {
        assert!(chunk_len >= 1, "mixed iteration needs a >= 1 token chunk");
        assert!(!kv_lens.is_empty(), "mixed iteration needs >= 1 decode sequence");
        self.post_iteration(
            tl,
            |s, cm| cm.mixed_stage_cost(s, chunk_start, chunk_len, kv_lens),
            self.chunk_overhead(chunk_start) + self.decode_handoff_overhead(),
        )
    }

    /// Replay one decode iteration over `kv_lens` onto the timeline.
    /// Returns the iteration's model-time duration plus its overlap-hidden
    /// collective seconds (0.0 at the default).
    pub fn post_decode(&self, tl: &mut Timeline, kv_lens: &[usize]) -> (f64, f64) {
        assert!(!kv_lens.is_empty(), "decode iteration needs >= 1 sequence");
        self.post_iteration(
            tl,
            |s, cm| cm.decode_stage_cost(s, kv_lens),
            self.cal.step_overhead_s + self.decode_handoff_overhead(),
        )
    }

    /// Walk the pipeline stages in order (one microbatch — stages are
    /// strictly serial), posting each stage's compute and *exposed*
    /// collective time on its TP group's ranks and coupling boundaries
    /// with P2P events (wire time is inside the sending stage's comm
    /// term). Ends with a coordinator barrier carrying the framework
    /// overhead. Returns (duration, overlap-hidden comm seconds).
    fn post_iteration(
        &self,
        tl: &mut Timeline,
        stage_cost: impl Fn(usize, &Self) -> (f64, f64, f64),
        overhead_s: f64,
    ) -> (f64, f64) {
        let p = self.layout().pp;
        let start = tl.max_time();
        let mut hidden_total = 0.0;
        for s in 0..p {
            let ranks = self.placement.tp_group(s);
            if s > 0 {
                let prev = self.placement.tp_group(s - 1);
                for (&a, &b) in prev.iter().zip(ranks.iter()) {
                    tl.post_p2p(a, b, 0.0);
                }
            }
            let (compute, comm, hidden) = stage_cost(s, self);
            hidden_total += hidden;
            for &r in &ranks {
                tl.post_compute(r, compute);
            }
            tl.post_collective(&ranks, comm);
        }
        tl.sync_all(overhead_s);
        (tl.max_time() - start, hidden_total)
    }

    /// What-if: price stage `s`'s TP AllReduce under the two-level
    /// hierarchical algorithm (intra-node ReduceScatter, inter-node
    /// AllReduce between node leaders, intra-node AllGather) on this
    /// placement's actual node shape — the bound on what a
    /// topology-aware algorithm could save over the measured flat ring
    /// ([`crate::cluster::NetModel::allreduce_two_level`]). Falls back to
    /// the flat slowest-link ring when the group does not split evenly
    /// across its nodes; degenerates to the flat NVLink ring for
    /// non-spanning groups.
    pub fn tp_allreduce_two_level(&self, pp_stage: usize, n_bytes: f64) -> CollectiveCost {
        let t = self.layout().tp;
        let ranks = self.placement.tp_group(pp_stage);
        // Ranks fill nodes in order, so distinct node ids are contiguous.
        let mut nodes: Vec<usize> =
            ranks.iter().map(|&r| self.placement.topology.node_of(r)).collect();
        nodes.dedup();
        let n_nodes = nodes.len();
        if n_nodes > 1 && t % n_nodes == 0 {
            // The hierarchical shape only exists if every node hosts
            // exactly t / n_nodes of the group's (contiguous) ranks — a
            // 3+1 split on 3-GPU nodes must fall back to the flat ring.
            let g = t / n_nodes;
            let even = ranks.chunks(g).all(|chunk| {
                let node = self.placement.topology.node_of(chunk[0]);
                chunk.iter().all(|&r| self.placement.topology.node_of(r) == node)
            });
            if even {
                return self.cal.net.allreduce_two_level(n_bytes, g, n_nodes);
            }
        }
        self.cal.net.allreduce(n_bytes, t, self.stage_crosses[pp_stage])
    }

    /// Wire bytes the tuning's quantized collectives kept off the fabric
    /// across a traced run: the paper-view AllReduce/AllGather corrected
    /// volume (the payloads the wire precision applies to — traces record
    /// logical BF16 bytes regardless of tuning) scaled by
    /// `1 − wire_bits/16`. Exactly 0.0 at the default 16-bit wire, with
    /// no summary walk.
    pub fn wire_saved_bytes(&self, summary: &TraceSummary) -> f64 {
        let tuning = self.cal.tuning;
        if !tuning.quantizes() {
            return 0.0;
        }
        let mut bytes = 0.0;
        for op in [CollectiveKind::AllReduce, CollectiveKind::AllGather] {
            for stage in [Stage::Prefill, Stage::Decode] {
                bytes += summary.paper_view(op, stage).corrected_volume_bytes;
            }
        }
        bytes * (1.0 - tuning.wire_factor())
    }

    /// Whether the TP group owning `rank` spans nodes (cached).
    fn group_crosses(&self, rank: usize) -> bool {
        let tp = self.layout().tp;
        let stage = (rank / tp).min(self.layout().pp.saturating_sub(1));
        self.stage_crosses[stage]
    }

    /// Price one traced communication record (seconds of modeled link
    /// time). P2P wire time is attributed to the `Send` record once —
    /// `Recv` prices to zero so per-stream sums do not double-count the
    /// same transfer. AllReduce/AllGather records honor the calibration's
    /// [`crate::cluster::CollectiveTuning`] (quantized-variant wire cost
    /// plus one quant/dequant pass-pair); every other op — and the whole
    /// dispatch at the default tuning — prices untuned.
    pub fn price_record(&self, rec: &CommRecord) -> f64 {
        if rec.op == CollectiveKind::Recv {
            return 0.0;
        }
        let bytes = rec.message_bytes() as f64;
        let total = self.placement.topology.total_gpus();
        let crosses = match rec.op {
            CollectiveKind::Send => match rec.peer {
                Some(peer) if rec.rank < total && peer < total => {
                    !self.placement.topology.same_node(rec.rank, peer)
                }
                _ => false,
            },
            _ => self.group_crosses(rec.rank.min(total.saturating_sub(1))),
        };
        let tuning = self.cal.tuning;
        if tuning.quantizes() {
            let quant = self.cal.compute.quant_dequant_time(bytes);
            match rec.op {
                CollectiveKind::AllReduce => {
                    return self
                        .cal
                        .net
                        .allreduce_tuned(bytes, rec.group_size, crosses, tuning)
                        .total()
                        + quant;
                }
                CollectiveKind::AllGather => {
                    return self
                        .cal
                        .net
                        .allgather_tuned(bytes, rec.group_size, crosses, tuning)
                        .total()
                        + quant;
                }
                _ => {}
            }
        }
        self.cal.net.collective(rec.op, bytes, rec.group_size, crosses).total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::Stage;
    use crate::model::DTYPE_BYTES_BF16;

    fn shape128() -> InferenceShape {
        InferenceShape::new(128, 128, DTYPE_BYTES_BF16)
    }

    fn cost(tp: usize, pp: usize) -> CostModel {
        CostModel::on_cardinal(ModelArch::llama32_3b(), ParallelLayout::new(tp, pp))
    }

    #[test]
    fn decode_step_is_the_singleton_iteration() {
        let cm = cost(4, 1);
        let s = shape128();
        let kv = s.prefill_len + s.decode_len / 2;
        assert_eq!(cm.decode_step_breakdown(s), cm.decode_iteration(&[kv]));
    }

    #[test]
    fn batched_decode_shares_weights_but_not_kv_or_wire() {
        let cm = cost(4, 1);
        let one = cm.decode_iteration(&[192]);
        let four = cm.decode_iteration(&[192, 192, 192, 192]);
        // Compute grows (KV per sequence) but far less than 4x (weights
        // stream once); comm grows with the [B, h] payload but keeps one
        // launch per collective.
        assert!(four.compute_s > one.compute_s);
        assert!(four.compute_s < 4.0 * one.compute_s);
        assert!(four.comm_s > one.comm_s);
        assert!(four.comm_s < 4.0 * one.comm_s);
        assert_eq!(four.overhead_s, one.overhead_s, "engine overhead is per iteration");
    }

    #[test]
    fn posted_prefill_matches_closed_form() {
        for (tp, pp) in [(2usize, 1usize), (4, 1), (1, 4), (2, 2), (8, 1), (2, 4)] {
            let cm = cost(tp, pp);
            let mut tl = Timeline::new(cm.placement.layout.world_size());
            let (dur, hidden) = cm.post_prefill(&mut tl, 128);
            assert_eq!(hidden, 0.0, "default tuning hides nothing");
            let closed = cm.prefill_breakdown(shape128()).total();
            assert!(
                (dur - closed).abs() <= 1e-9 * closed.abs().max(1.0),
                "tp={tp} pp={pp}: posted {dur} vs closed {closed}"
            );
            assert_eq!(tl.max_time(), dur, "first iteration starts at t=0");
        }
    }

    #[test]
    fn posted_decode_matches_closed_form_and_accumulates() {
        for (tp, pp) in [(2usize, 1usize), (1, 4), (2, 2), (8, 1)] {
            let cm = cost(tp, pp);
            let s = shape128();
            let kv = s.prefill_len + s.decode_len / 2;
            let mut tl = Timeline::new(cm.placement.layout.world_size());
            let (d1, _) = cm.post_decode(&mut tl, &[kv]);
            let closed = cm.decode_step_breakdown(s).total();
            assert!(
                (d1 - closed).abs() <= 1e-9 * closed.abs().max(1.0),
                "tp={tp} pp={pp}: posted {d1} vs closed {closed}"
            );
            let before = tl.max_time();
            let (d2, _) = cm.post_decode(&mut tl, &[kv + 1]);
            assert!((tl.max_time() - (before + d2)).abs() < 1e-15, "clock accumulates");
        }
    }

    #[test]
    fn prefill_price_and_comm_bytes_follow_the_closed_forms() {
        for (tp, pp) in [(2usize, 1usize), (4, 1), (2, 2), (1, 4)] {
            let cm = cost(tp, pp);
            // prefill_price is exactly the breakdown total at sd=1 (only
            // prefill_len matters to the breakdown).
            let direct = cm.prefill_breakdown(InferenceShape::new(96, 1, 2)).total();
            assert_eq!(cm.prefill_price(96), direct, "tp={tp} pp={pp}");
            // Strictly monotone in prompt length, and a cached-prefix
            // saving (full minus suffix) is positive and below the full
            // price.
            assert!(cm.prefill_price(128) > cm.prefill_price(96));
            let saved = cm.prefill_price(128) - cm.prefill_price(32);
            assert!(saved > 0.0 && saved < cm.prefill_price(128));
            // Comm bytes match the volume model at sd=1 and the
            // saved-bytes difference cancels the logits gather.
            let vm = crate::analysis::VolumeModel::new(cm.arch.clone());
            let vol = vm.volume(cm.placement.layout, InferenceShape::new(96, 1, 2));
            assert_eq!(cm.prefill_comm_bytes(96), vol.total());
            let saved_bytes = cm.prefill_comm_bytes(128) - cm.prefill_comm_bytes(32);
            let no_gather = |n: usize| {
                let v = vm.volume(cm.placement.layout, InferenceShape::new(n, 1, 2));
                v.total() - v.gather
            };
            assert!(
                (saved_bytes - (no_gather(128) - no_gather(32))).abs()
                    <= 1e-9 * saved_bytes.abs().max(1.0),
                "tp={tp} pp={pp}: gather term must cancel in the difference"
            );
        }
    }

    #[test]
    fn chunk_breakdowns_never_underprice_the_one_shot_prefill() {
        // Property: for every layout and chunk budget, Σ chunk breakdowns
        // ≥ the one-shot prefill — interleaving never creates free work.
        // Compute telescopes to (float-)equality; the extra collective
        // launches and per-chunk logits gathers make comm strictly grow,
        // and the per-chunk step overheads make overhead grow.
        for (tp, pp) in [(1usize, 1usize), (2, 1), (4, 1), (1, 4), (2, 2), (8, 1)] {
            let cm = cost(tp, pp);
            for (sp, budget) in [(128usize, 32usize), (257, 64), (96, 100), (512, 128)] {
                let one_shot = cm.prefill_breakdown(InferenceShape::new(sp, 1, 2));
                let mut sum = PhaseBreakdown::default();
                let mut chunks = 0usize;
                let mut start = 0usize;
                while start < sp {
                    let len = budget.min(sp - start);
                    let b = cm.prefill_chunk_breakdown(start, len);
                    sum.compute_s += b.compute_s;
                    sum.comm_s += b.comm_s;
                    sum.overhead_s += b.overhead_s;
                    chunks += 1;
                    start += len;
                }
                assert!(
                    (sum.compute_s - one_shot.compute_s).abs()
                        <= 1e-9 * one_shot.compute_s.max(1e-30),
                    "tp={tp} pp={pp} sp={sp} budget={budget}: chunk compute telescopes"
                );
                assert!(
                    sum.total() >= one_shot.total() * (1.0 - 1e-12),
                    "tp={tp} pp={pp} sp={sp} budget={budget}: Σ chunks {} < one-shot {}",
                    sum.total(),
                    one_shot.total()
                );
                if chunks > 1 {
                    assert!(
                        sum.total() > one_shot.total(),
                        "tp={tp} pp={pp} sp={sp} budget={budget}: a real split must \
                         cost strictly more (extra launches + step overheads)"
                    );
                    if tp > 1 {
                        assert!(sum.comm_s > one_shot.comm_s, "extra gathers per chunk");
                    }
                    assert!(sum.overhead_s > one_shot.overhead_s, "per-chunk step overhead");
                }
            }
        }
    }

    #[test]
    fn mixed_iteration_prices_chunk_and_victims_as_one_fused_launch() {
        let cm = cost(4, 1);
        let kv = [200usize, 150, 300];
        let mixed = cm.mixed_iteration(64, 32, &kv);
        let chunk = cm.prefill_chunk_breakdown(64, 32);
        let decode = cm.decode_iteration(&kv);
        // Fused compute is the sum of the parts (weights stream per term
        // today; the fusion saving is in comm launches and overhead).
        assert!(
            (mixed.compute_s - (chunk.compute_s + decode.compute_s)).abs()
                <= 1e-12 * (chunk.compute_s + decode.compute_s),
            "mixed compute {} vs parts {}",
            mixed.compute_s,
            chunk.compute_s + decode.compute_s
        );
        // One fused launch per collective: cheaper than launching the
        // chunk's and the decode step's collectives separately...
        assert!(mixed.comm_s < chunk.comm_s + decode.comm_s);
        // ...but dearer than either alone (the payload grew).
        assert!(mixed.comm_s > chunk.comm_s && mixed.comm_s > decode.comm_s);
        // One step's overhead, not two: the chunk's plus the decode
        // handoff (0 at pp=1, so here exactly the chunk's).
        assert_eq!(mixed.overhead_s, chunk.overhead_s);
        // The decode victims see real interference: the mixed iteration
        // costs strictly more than the pure decode step they would have
        // run alone.
        assert!(mixed.total() > decode.total());
        // First-chunk mixed steps pay the prefill intake once.
        let first = cm.mixed_iteration(0, 32, &kv);
        assert!(first.overhead_s > cm.mixed_iteration(32, 32, &kv).overhead_s);
    }

    #[test]
    fn posted_chunk_and_mixed_match_their_closed_forms() {
        for (tp, pp) in [(2usize, 1usize), (4, 1), (1, 4), (2, 2), (8, 1), (2, 4)] {
            let cm = cost(tp, pp);
            let mut tl = Timeline::new(cm.placement.layout.world_size());
            let (d1, h1) = cm.post_prefill_chunk(&mut tl, 0, 64);
            assert_eq!(h1, 0.0, "default tuning hides nothing");
            let closed = cm.prefill_chunk_breakdown(0, 64).total();
            assert!(
                (d1 - closed).abs() <= 1e-9 * closed.abs().max(1.0),
                "tp={tp} pp={pp}: posted chunk {d1} vs closed {closed}"
            );
            let before = tl.max_time();
            let (d2, _) = cm.post_mixed(&mut tl, 64, 64, &[128, 192]);
            let closed2 = cm.mixed_iteration(64, 64, &[128, 192]).total();
            assert!(
                (d2 - closed2).abs() <= 1e-9 * closed2.abs().max(1.0),
                "tp={tp} pp={pp}: posted mixed {d2} vs closed {closed2}"
            );
            assert!((tl.max_time() - (before + d2)).abs() < 1e-12, "clock accumulates");
        }
    }

    #[test]
    fn quantized_wire_shrinks_comm_and_overlap_hides_it() {
        use crate::cluster::CollectiveTuning;
        let base = cost(4, 1);
        let s = shape128();
        let b0 = base.prefill_breakdown(s);
        let d0 = base.decode_step_breakdown(s);
        assert_eq!(base.prefill_hidden_comm_s(128), 0.0, "default hides nothing");

        // An int8 wire shrinks comm in both phases without touching
        // compute or overhead.
        let mut int8 = base.clone();
        int8.cal.tuning = CollectiveTuning::new(8, 0.0);
        let b8 = int8.prefill_breakdown(s);
        let d8 = int8.decode_step_breakdown(s);
        assert!(b8.comm_s < b0.comm_s, "{} vs {}", b8.comm_s, b0.comm_s);
        assert!(d8.comm_s < d0.comm_s);
        assert_eq!(b8.compute_s, b0.compute_s);
        assert_eq!(b8.overhead_s, b0.overhead_s);

        // Overlap on an untouched wire: exposed + hidden reassembles the
        // untuned comm exactly, and hidden stays under ov · compute.
        let mut ov = base.clone();
        ov.cal.tuning = CollectiveTuning::new(16, 0.5);
        let bov = ov.prefill_breakdown(s);
        let hidden = ov.prefill_hidden_comm_s(128);
        assert!(hidden > 0.0 && bov.comm_s < b0.comm_s);
        assert!(
            (bov.comm_s + hidden - b0.comm_s).abs() <= 1e-12 * b0.comm_s,
            "exposed {} + hidden {hidden} must reassemble untuned {}",
            bov.comm_s,
            b0.comm_s
        );
        assert!(hidden <= 0.5 * b0.compute_s * (1.0 + 1e-12));
        let kv = s.prefill_len + s.decode_len / 2;
        let dh = ov.decode_hidden_comm_s(&[kv]);
        let dov = ov.decode_step_breakdown(s);
        assert!((dov.comm_s + dh - d0.comm_s).abs() <= 1e-12 * d0.comm_s);

        // The posting path reports the same hidden seconds it withheld.
        let mut tl = Timeline::new(ov.placement.layout.world_size());
        let (_, posted_hidden) = ov.post_prefill(&mut tl, 128);
        assert_eq!(posted_hidden, hidden);
    }

    #[test]
    fn tuned_price_record_matches_variant_formulas() {
        use crate::cluster::CollectiveTuning;
        let mut cm = cost(4, 1);
        cm.cal.tuning = CollectiveTuning::new(8, 0.0);
        let rec = |op: CollectiveKind| CommRecord {
            op,
            stage: Stage::Decode,
            rank: 0,
            group_size: 4,
            shape: vec![4096],
            elems: 4096,
            dtype_bytes: 2,
            peer: None,
            step: None,
            batch: None,
            modeled_s: 0.0,
        };
        let quant = cm.cal.compute.quant_dequant_time(8192.0);
        let ar = cm.price_record(&rec(CollectiveKind::AllReduce));
        let want =
            cm.cal.net.allreduce_tuned(8192.0, 4, false, cm.cal.tuning).total() + quant;
        assert!((ar - want).abs() < 1e-18);
        let ag = cm.price_record(&rec(CollectiveKind::AllGather));
        let want_ag =
            cm.cal.net.allgather_tuned(8192.0, 4, false, cm.cal.tuning).total() + quant;
        assert!((ag - want_ag).abs() < 1e-18);
        // Other ops are untouched by the wire precision.
        let base = cost(4, 1);
        assert_eq!(
            cm.price_record(&rec(CollectiveKind::Gather)),
            base.price_record(&rec(CollectiveKind::Gather))
        );
        // And cheaper than the untuned pricing of the same records.
        assert!(ar < base.price_record(&rec(CollectiveKind::AllReduce)));
    }

    #[test]
    fn price_record_matches_netmodel_costs() {
        let cm = cost(4, 1); // one node: intra-node TP group
        let rec = |op: CollectiveKind, elems: usize, peer: Option<usize>| CommRecord {
            op,
            stage: Stage::Decode,
            rank: 0,
            group_size: 4,
            shape: vec![elems],
            elems,
            dtype_bytes: 2,
            peer,
            step: None,
            batch: None,
            modeled_s: 0.0,
        };
        let ar = cm.price_record(&rec(CollectiveKind::AllReduce, 4096, None));
        let direct = cm.cal.net.allreduce(8192.0, 4, false).total();
        assert!((ar - direct).abs() < 1e-15);
        assert_eq!(cm.price_record(&rec(CollectiveKind::Recv, 4096, Some(1))), 0.0);
        let send = cm.price_record(&rec(CollectiveKind::Send, 4096, Some(1)));
        assert!((send - cm.cal.net.p2p(8192.0, false).total()).abs() < 1e-15);
        assert!(cm.price_record(&rec(CollectiveKind::Gather, 1024, None)) > 0.0);
    }

    #[test]
    fn two_level_what_if_undercuts_the_flat_spanning_ring() {
        // TP=8 over two cardinal nodes: the hierarchical algorithm beats
        // the flat slowest-link ring the calibration measures, but never
        // the same group on pure NVLink.
        let cm = cost(8, 1);
        for bytes in [8192.0, 1.0e6, 1.0e9] {
            let flat_ib = cm.cal.net.allreduce(bytes, 8, true).total();
            let flat_nv = cm.cal.net.allreduce(bytes, 8, false).total();
            let what_if = cm.tp_allreduce_two_level(0, bytes).total();
            assert!(what_if < flat_ib, "bytes={bytes}: {what_if} vs flat IB {flat_ib}");
            assert!(what_if >= flat_nv, "bytes={bytes}");
        }
        // Non-spanning groups degenerate to the flat NVLink ring.
        let intra = cost(4, 1);
        assert_eq!(
            intra.tp_allreduce_two_level(0, 1.0e6),
            intra.cal.net.allreduce(1.0e6, 4, false)
        );
        // An uneven split (3+1 ranks across 3-GPU nodes) has no two-level
        // shape: fall back to the flat slowest-link ring.
        let uneven = CostModel::new(
            ModelArch::llama32_3b(),
            Placement::new(Topology::new(2, 3), ParallelLayout::new(4, 1)).unwrap(),
            crate::perfmodel::Calibration::default(),
        );
        assert_eq!(
            uneven.tp_allreduce_two_level(0, 1.0e6),
            uneven.cal.net.allreduce(1.0e6, 4, true)
        );
    }

    #[test]
    fn cross_node_groups_price_higher() {
        let intra = cost(4, 1);
        let cross = cost(8, 1); // spans two cardinal nodes
        let rec = CommRecord {
            op: CollectiveKind::AllReduce,
            stage: Stage::Decode,
            rank: 0,
            group_size: 4,
            shape: vec![4096],
            elems: 4096,
            dtype_bytes: 2,
            peer: None,
            step: None,
            batch: None,
            modeled_s: 0.0,
        };
        assert!(cross.price_record(&rec) > intra.price_record(&rec));
    }
}
