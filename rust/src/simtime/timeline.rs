//! Per-rank virtual clocks — the discrete-cost engine's notion of time.
//!
//! A [`Timeline`] holds one monotone clock per global rank. Priced events
//! are *posted* onto it: local compute advances one rank, a collective
//! synchronizes its group to the latest member before advancing everyone
//! by the op's cost (collectives are rendezvous operations in our engine —
//! no member leaves before the slowest arrives), a P2P transfer couples a
//! sender/receiver pair, and an overlap window advances by
//! `max(compute, comm)` (a primitive for overlap-aware cost models; the
//! serving path currently posts compute, collective, P2P and barrier
//! events only — vLLM V0 eager mode does not overlap). `max_time()` is
//! the makespan — the model-time "now" the serving layer reports SLOs in.

/// Per-rank virtual clocks (seconds since the timeline's epoch).
#[derive(Debug, Clone, PartialEq)]
pub struct Timeline {
    clocks: Vec<f64>,
}

impl Timeline {
    /// A timeline over `world_size` ranks, all at t = 0.
    pub fn new(world_size: usize) -> Self {
        assert!(world_size >= 1, "timeline needs at least one rank");
        Self { clocks: vec![0.0; world_size] }
    }

    /// Number of ranks.
    pub fn world_size(&self) -> usize {
        self.clocks.len()
    }

    /// Current clock of one rank.
    pub fn now(&self, rank: usize) -> f64 {
        self.clocks[rank]
    }

    /// The latest rank clock — the makespan of everything posted so far.
    pub fn max_time(&self) -> f64 {
        self.clocks.iter().copied().fold(0.0, f64::max)
    }

    /// Local compute on one rank.
    pub fn post_compute(&mut self, rank: usize, cost_s: f64) {
        debug_assert!(cost_s >= 0.0);
        self.clocks[rank] += cost_s;
    }

    /// A collective over `ranks`: every member blocks until the slowest
    /// arrives, then all advance together by `cost_s`.
    pub fn post_collective(&mut self, ranks: &[usize], cost_s: f64) {
        debug_assert!(cost_s >= 0.0);
        let sync = ranks.iter().map(|&r| self.clocks[r]).fold(0.0, f64::max);
        for &r in ranks {
            self.clocks[r] = sync + cost_s;
        }
    }

    /// A point-to-point transfer: sender and receiver rendezvous (our
    /// engine's sends block until the wire drains), then both advance by
    /// the wire cost.
    pub fn post_p2p(&mut self, src: usize, dst: usize, cost_s: f64) {
        debug_assert!(cost_s >= 0.0);
        let sync = self.clocks[src].max(self.clocks[dst]);
        self.clocks[src] = sync + cost_s;
        self.clocks[dst] = sync + cost_s;
    }

    /// An overlap window on one rank: compute and communication proceed
    /// concurrently, the clock advances by the longer of the two.
    pub fn post_overlap(&mut self, rank: usize, compute_s: f64, comm_s: f64) {
        debug_assert!(compute_s >= 0.0 && comm_s >= 0.0);
        self.clocks[rank] += compute_s.max(comm_s);
    }

    /// Global barrier plus `extra_s` of synchronized time: every rank
    /// advances to the current makespan, then by `extra_s` (the
    /// coordinator round-trip at the end of an engine iteration).
    pub fn sync_all(&mut self, extra_s: f64) {
        debug_assert!(extra_s >= 0.0);
        let t = self.max_time() + extra_s;
        for c in &mut self.clocks {
            *c = t;
        }
    }

    /// Advance every rank at least to `t` (idle time — e.g. a serving loop
    /// waiting for the next open-loop arrival). Clocks already past `t`
    /// are untouched.
    pub fn advance_all_to(&mut self, t: f64) {
        for c in &mut self.clocks {
            if *c < t {
                *c = t;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_and_collective_advance_clocks() {
        let mut tl = Timeline::new(4);
        tl.post_compute(0, 1.0);
        tl.post_compute(1, 3.0);
        assert_eq!(tl.now(0), 1.0);
        assert_eq!(tl.max_time(), 3.0);
        // Collective syncs members 0..2 to the slowest (3.0) then adds cost.
        tl.post_collective(&[0, 1], 0.5);
        assert_eq!(tl.now(0), 3.5);
        assert_eq!(tl.now(1), 3.5);
        assert_eq!(tl.now(2), 0.0, "non-members untouched");
    }

    #[test]
    fn p2p_couples_the_pair() {
        let mut tl = Timeline::new(2);
        tl.post_compute(0, 2.0);
        tl.post_p2p(0, 1, 0.25);
        assert_eq!(tl.now(0), 2.25);
        assert_eq!(tl.now(1), 2.25, "receiver waits for the sender");
    }

    #[test]
    fn overlap_takes_the_max() {
        let mut tl = Timeline::new(1);
        tl.post_overlap(0, 2.0, 3.0);
        assert_eq!(tl.now(0), 3.0);
        tl.post_overlap(0, 5.0, 1.0);
        assert_eq!(tl.now(0), 8.0);
    }

    #[test]
    fn sync_and_advance() {
        let mut tl = Timeline::new(3);
        tl.post_compute(2, 4.0);
        tl.sync_all(1.0);
        assert_eq!((tl.now(0), tl.now(1), tl.now(2)), (5.0, 5.0, 5.0));
        tl.advance_all_to(4.0);
        assert_eq!(tl.now(0), 5.0, "advance_all_to never rewinds");
        tl.advance_all_to(6.0);
        assert_eq!(tl.max_time(), 6.0);
    }
}
