"""Layer-2 JAX model: TP-shardable Llama-style transformer segments.

The serving engine (Rust, L3) composes distributed inference out of
*segment* executables whose boundaries are exactly the points where vLLM
places NCCL collectives (DESIGN.md §6):

    embed_partial  -> AllReduce                       (vocab-parallel embed)
    attn_partial   -> AllReduce  (per layer)          (row-parallel out-proj)
    mlp_partial    -> AllReduce  (per layer)          (row-parallel down-proj)
    logits_partial -> Gather                          (column-parallel lm head)

Each segment is a pure function of (activations, kv cache, weights) so
``aot.py`` can lower it once per tensor-parallel degree ``t`` with weights as
runtime parameters; every TP rank then runs the *same* executable with its
own weight shard. Pipeline parallelism needs no extra executables: a stage
is a Rust-side loop over its local layers.

Sharding follows Megatron-LM (the scheme vLLM implements and the paper
analyzes in §III.A):
  - attention: QKV projections column-parallel (each rank owns a/t heads),
    out-projection row-parallel -> partial [S, h] summed by AllReduce;
  - MLP: gate/up column-parallel (f/t columns), down row-parallel;
  - embedding: vocab-parallel rows, partial summed by AllReduce;
  - LM head: column-parallel, logits slice [v/t] gathered.

All math is f32 (deterministic CPU PJRT); the analytical byte model in Rust
is parameterized on dtype width separately.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernels
from .kernels import rmsnorm as rmsnorm_kernel
from .kernels import swiglu as swiglu_kernels


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (see rust/src/model/arch.rs for the
    paper-scale registry; this mirrors the fields the analysis needs)."""

    vocab: int = 512
    hidden: int = 256
    intermediate: int = 768
    layers: int = 4
    heads: int = 8
    head_dim: int = 32
    max_seq: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    def validate_tp(self, t: int) -> None:
        if self.heads % t or self.intermediate % t or self.vocab % t:
            raise ValueError(f"config not divisible by tp={t}")

    @property
    def q_dim(self) -> int:
        return self.heads * self.head_dim


TINY = ModelConfig()  # the numeric-mode model served end-to-end by Rust


def _block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (Pallas block-shape helper)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Rotary position embedding (interleaved-pair convention)
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE. x: [S, a, d]; positions: [S] int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)  # [d/2]
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, d/2]
    cos = jnp.cos(angles)[:, None, :]  # [S, 1, d/2]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Segments (each lowered to one HLO executable per (t, S) by aot.py)
# ---------------------------------------------------------------------------


def embed_partial(
    cfg: ModelConfig, t: int, tokens: jax.Array, w_emb_shard: jax.Array, rank_offset: jax.Array
) -> jax.Array:
    """Vocab-parallel embedding: rank holds rows [off, off + v/t).

    tokens: [S] int32; w_emb_shard: [v/t, h]; rank_offset: [1] int32.
    Returns the *partial* embedding [S, h] (zeros for out-of-shard tokens);
    the Rust engine AllReduces partials into the full embedding — the
    "(2L+1)" +1 AllReduce of Eq. 1.
    """
    v_local = cfg.vocab // t
    idx = tokens.astype(jnp.int32) - rank_offset[0]
    valid = (idx >= 0) & (idx < v_local)
    safe = jnp.clip(idx, 0, v_local - 1)
    out = w_emb_shard[safe]  # [S, h]
    return jnp.where(valid[:, None], out, 0.0)


def attn_partial(
    cfg: ModelConfig,
    t: int,
    x: jax.Array,  # [S, h] full (post-AllReduce) residual stream
    k_cache: jax.Array,  # [T, a/t, d]
    v_cache: jax.Array,  # [T, a/t, d]
    pos: jax.Array,  # [1] int32 — write offset / number of tokens already cached
    norm_w: jax.Array,  # [h]
    wq: jax.Array,  # [h, (a/t)*d]
    wk: jax.Array,  # [h, (a/t)*d]
    wv: jax.Array,  # [h, (a/t)*d]
    wo: jax.Array,  # [(a/t)*d, h]
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Column-parallel QKV + attention over local heads + row-parallel out-proj.

    Returns (partial_out [S, h], k_cache', v_cache'). The partial output is
    this rank's contribution to the attention block output; the engine
    AllReduces it (first of the two per-layer AllReduces of Eq. 1).
    """
    s_len = x.shape[0]
    a_local = cfg.heads // t
    d = cfg.head_dim
    xn = rmsnorm_kernel.rmsnorm(x, norm_w, cfg.norm_eps, block_m=_block(s_len, 32))
    q = (xn @ wq).reshape(s_len, a_local, d)
    k = (xn @ wk).reshape(s_len, a_local, d)
    v = (xn @ wv).reshape(s_len, a_local, d)
    positions = pos[0] + jnp.arange(s_len, dtype=jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (pos[0], 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (pos[0], 0, 0))

    if s_len == 1:
        # Decode step: flash-decoding Pallas kernel over the padded cache.
        out = attn_kernels.decode_attention(
            q[0], k_cache, v_cache, pos + 1, block_t=_block(cfg.max_seq, 64)
        )[None, :, :]  # [1, a/t, d]
    else:
        # Prefill: causal flash attention over the prompt (pos[0] == 0).
        bq = _block(s_len, 32)
        out = attn_kernels.prefill_attention(
            q, k, v, block_q=bq, block_t=_block(bq, 32)
        )
    partial = out.reshape(s_len, a_local * d) @ wo  # [S, h] partial sum
    return partial, k_cache, v_cache


def mlp_partial(
    cfg: ModelConfig,
    t: int,
    x: jax.Array,  # [S, h] full residual stream
    norm_w: jax.Array,  # [h]
    w_gate: jax.Array,  # [h, f/t]
    w_up: jax.Array,  # [h, f/t]
    w_down: jax.Array,  # [f/t, h]
) -> jax.Array:
    """Column-parallel gate/up + fused SwiGLU kernel + row-parallel down.

    Returns partial [S, h]; AllReduced by the engine (second per-layer
    AllReduce of Eq. 1).
    """
    xn = rmsnorm_kernel.rmsnorm(x, norm_w, cfg.norm_eps, block_m=_block(x.shape[0], 32))
    f_local = w_gate.shape[1]
    act = swiglu_kernels.swiglu(
        xn, w_gate, w_up,
        block_m=_block(x.shape[0], 32),
        block_n=_block(f_local, 128),
    )
    return act @ w_down


def logits_partial(
    cfg: ModelConfig,
    t: int,
    x: jax.Array,  # [S, h]
    norm_w: jax.Array,  # [h]
    w_lm: jax.Array,  # [h, v/t]
) -> jax.Array:
    """Final norm + column-parallel LM head on the *last* token.

    Returns [1, v/t]; ranks' slices are Gathered by the engine (the Gather
    term of Eq. 1) and argmax-sampled by the coordinator.
    """
    last = x[-1:, :]
    xn = rmsnorm_kernel.rmsnorm(last, norm_w, cfg.norm_eps, block_m=1)
    return xn @ w_lm


# ---------------------------------------------------------------------------
# Whole-model single-device graphs (oracle + fused fast path)
# ---------------------------------------------------------------------------


def full_step(
    cfg: ModelConfig,
    tokens: jax.Array,  # [S] int32
    pos: jax.Array,  # [1] int32
    k_caches: jax.Array,  # [L, T, a, d]
    v_caches: jax.Array,  # [L, T, a, d]
    weights: dict,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unsharded forward over all layers: the numeric oracle for segment
    composition, and the fused single-executable fast path (t=1, p=1).

    Returns (logits [1, v], k_caches', v_caches').
    """
    x = embed_partial(cfg, 1, tokens, weights["embed"], jnp.zeros((1,), jnp.int32))
    new_k, new_v = [], []
    for layer in range(cfg.layers):
        lw = weights["layers"][layer]
        pa, kc, vc = attn_partial(
            cfg, 1, x, k_caches[layer], v_caches[layer], pos,
            lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
        )
        new_k.append(kc)
        new_v.append(vc)
        x = x + pa
        pm = mlp_partial(cfg, 1, x, lw["mlp_norm"], lw["w_gate"], lw["w_up"], lw["w_down"])
        x = x + pm
    logits = logits_partial(cfg, 1, x, weights["final_norm"], weights["lm_head"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


# ---------------------------------------------------------------------------
# Deterministic weight generation + TP sharding
# ---------------------------------------------------------------------------


def init_weights(cfg: ModelConfig, seed: int = 0) -> dict:
    """Deterministic tiny-model weights (scaled for stable forward pass)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 6 + 9 * cfg.layers))

    def mat(shape, scale):
        return (jax.random.normal(next(keys), shape, jnp.float32) * scale)

    h, f, v, qd = cfg.hidden, cfg.intermediate, cfg.vocab, cfg.q_dim
    w = {
        "embed": mat((v, h), 0.02),
        "final_norm": jnp.ones((h,), jnp.float32),
        "lm_head": mat((h, v), 1.0 / math.sqrt(h)),
        "layers": [],
    }
    for _ in range(cfg.layers):
        w["layers"].append(
            {
                "attn_norm": jnp.ones((h,), jnp.float32),
                "wq": mat((h, qd), 1.0 / math.sqrt(h)),
                "wk": mat((h, qd), 1.0 / math.sqrt(h)),
                "wv": mat((h, qd), 1.0 / math.sqrt(h)),
                "wo": mat((qd, h), 1.0 / math.sqrt(qd)),
                "mlp_norm": jnp.ones((h,), jnp.float32),
                "w_gate": mat((h, f), 1.0 / math.sqrt(h)),
                "w_up": mat((h, f), 1.0 / math.sqrt(h)),
                "w_down": mat((f, h), 1.0 / math.sqrt(f)),
            }
        )
    return w


def shard_weights(cfg: ModelConfig, weights: dict, t: int, rank: int) -> dict:
    """Extract rank's Megatron-style shard of every weight tensor."""
    cfg.validate_tp(t)
    a_local = cfg.heads // t
    d = cfg.head_dim
    f_local = cfg.intermediate // t
    v_local = cfg.vocab // t

    def col(w, n_local):  # column-parallel: split dim 1
        return w[:, rank * n_local : (rank + 1) * n_local]

    def row(w, n_local):  # row-parallel: split dim 0
        return w[rank * n_local : (rank + 1) * n_local, :]

    out = {
        "embed": row(weights["embed"], v_local),
        "final_norm": weights["final_norm"],
        "lm_head": col(weights["lm_head"], v_local),
        "layers": [],
    }
    qd_local = a_local * d
    for lw in weights["layers"]:
        out["layers"].append(
            {
                "attn_norm": lw["attn_norm"],
                "wq": col(lw["wq"], qd_local),
                "wk": col(lw["wk"], qd_local),
                "wv": col(lw["wv"], qd_local),
                "wo": row(lw["wo"], qd_local),
                "mlp_norm": lw["mlp_norm"],
                "w_gate": col(lw["w_gate"], f_local),
                "w_up": col(lw["w_up"], f_local),
                "w_down": row(lw["w_down"], f_local),
            }
        )
    return out
