"""AOT compilation: lower L2 segments to HLO *text* + weight shard blobs.

Run once at build time (``make artifacts``); the Rust engine then serves
without Python. For every tensor-parallel degree ``t`` we emit one HLO file
per (segment, phase) — the executable is rank-agnostic, each rank feeds its
own weight shard at run time:

    artifacts/
      meta.json                      model dims, Sp, artifact inventory
      {embed,attn,mlp,logits}_{prefill,decode}_t{t}.hlo.txt
      full_{prefill,decode}_t1.hlo.txt      fused whole-model graphs (oracle
                                            + single-worker fast path)
      weights_t{t}_rank{r}.bin       f32 LE tensors, canonical order
      weights_t{t}_rank{r}.json      manifest: name/shape/offset per tensor

Interchange is HLO **text**, not ``HloModuleProto.serialize()``: jax >= 0.5
emits 64-bit instruction ids that the crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md). All graphs
are lowered with ``return_tuple=True`` and unwrapped on the Rust side.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def segment_specs(cfg: M.ModelConfig, t: int, s_len: int) -> dict:
    """Example-arg specs for each segment at (t, S)."""
    h, d = cfg.hidden, cfg.head_dim
    a_local = cfg.heads // t
    f_local = cfg.intermediate // t
    v_local = cfg.vocab // t
    qd_local = a_local * d
    T = cfg.max_seq
    i32 = jnp.int32
    return {
        "embed": (
            functools.partial(M.embed_partial, cfg, t),
            [_spec((s_len,), i32), _spec((v_local, h)), _spec((1,), i32)],
        ),
        "attn": (
            functools.partial(M.attn_partial, cfg, t),
            [
                _spec((s_len, h)),
                _spec((T, a_local, d)),
                _spec((T, a_local, d)),
                _spec((1,), i32),
                _spec((h,)),
                _spec((h, qd_local)),
                _spec((h, qd_local)),
                _spec((h, qd_local)),
                _spec((qd_local, h)),
            ],
        ),
        "mlp": (
            functools.partial(M.mlp_partial, cfg, t),
            [
                _spec((s_len, h)),
                _spec((h,)),
                _spec((h, f_local)),
                _spec((h, f_local)),
                _spec((f_local, h)),
            ],
        ),
        "logits": (
            functools.partial(M.logits_partial, cfg, t),
            [_spec((s_len, h)), _spec((h,)), _spec((h, v_local))],
        ),
    }


# Canonical per-shard tensor order shared with rust/src/runtime/weights.rs.
def shard_tensor_list(cfg: M.ModelConfig, shard: dict) -> list[tuple[str, np.ndarray]]:
    out = [
        ("embed", shard["embed"]),
        ("final_norm", shard["final_norm"]),
        ("lm_head", shard["lm_head"]),
    ]
    for i, lw in enumerate(shard["layers"]):
        for name in (
            "attn_norm", "wq", "wk", "wv", "wo",
            "mlp_norm", "w_gate", "w_up", "w_down",
        ):
            out.append((f"layer{i}.{name}", lw[name]))
    return [(n, np.asarray(a, np.float32)) for n, a in out]


def write_shard(out_dir: str, t: int, rank: int, tensors) -> None:
    manifest, offset = [], 0
    blob_path = os.path.join(out_dir, f"weights_t{t}_rank{rank}.bin")
    with open(blob_path, "wb") as f:
        for name, arr in tensors:
            data = arr.tobytes()  # f32 little-endian, C order
            manifest.append({"name": name, "shape": list(arr.shape), "offset": offset})
            f.write(data)
            offset += len(data)
    with open(os.path.join(out_dir, f"weights_t{t}_rank{rank}.json"), "w") as f:
        json.dump({"tensors": manifest, "total_bytes": offset}, f, indent=1)
    # Line-based manifest for the Rust loader (std-only, no JSON parser):
    #   total_bytes <n>
    #   <name> <offset> <dim0,dim1,...>
    with open(os.path.join(out_dir, f"weights_t{t}_rank{rank}.manifest"), "w") as f:
        f.write(f"total_bytes {offset}\n")
        for e in manifest:
            dims = ",".join(str(d) for d in e["shape"])
            f.write(f"{e['name']} {e['offset']} {dims}\n")


def full_step_flat(cfg: M.ModelConfig, tokens, pos, k_caches, v_caches, *flat):
    """full_step with weights flattened into positional params (AOT-friendly)."""
    weights = {"embed": flat[0], "final_norm": flat[1], "lm_head": flat[2], "layers": []}
    names = ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "w_gate", "w_up", "w_down")
    for i in range(cfg.layers):
        base = 3 + i * len(names)
        weights["layers"].append(dict(zip(names, flat[base : base + len(names)])))
    return M.full_step(cfg, tokens, pos, k_caches, v_caches, weights)


def full_specs(cfg: M.ModelConfig, s_len: int) -> list:
    h, f, v, qd, d = cfg.hidden, cfg.intermediate, cfg.vocab, cfg.q_dim, cfg.head_dim
    T, L, a = cfg.max_seq, cfg.layers, cfg.heads
    specs = [
        _spec((s_len,), jnp.int32),
        _spec((1,), jnp.int32),
        _spec((L, T, a, d)),
        _spec((L, T, a, d)),
        _spec((v, h)),
        _spec((h,)),
        _spec((h, v)),
    ]
    for _ in range(L):
        specs += [
            _spec((h,)), _spec((h, qd)), _spec((h, qd)), _spec((h, qd)),
            _spec((qd, h)), _spec((h,)), _spec((h, f)), _spec((h, f)),
            _spec((f, h)),
        ]
    return specs


def build(out_dir: str, tp_degrees: list[int], sp: int, seed: int) -> list[str]:
    cfg = M.TINY
    os.makedirs(out_dir, exist_ok=True)
    written = []

    def emit(name: str, fn, specs) -> None:
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(f"{name}.hlo.txt")
        print(f"  {name}.hlo.txt ({len(text)} chars)")

    for t in tp_degrees:
        cfg.validate_tp(t)
        for phase, s_len in (("prefill", sp), ("decode", 1)):
            for seg, (fn, specs) in segment_specs(cfg, t, s_len).items():
                emit(f"{seg}_{phase}_t{t}", fn, specs)

    # Fused whole-model graphs (t=1): numeric oracle + fast path.
    for phase, s_len in (("prefill", sp), ("decode", 1)):
        emit(
            f"full_{phase}_t1",
            functools.partial(full_step_flat, cfg),
            full_specs(cfg, s_len),
        )

    weights = M.init_weights(cfg, seed)
    for t in tp_degrees:
        for rank in range(t):
            shard = M.shard_weights(cfg, weights, t, rank)
            write_shard(out_dir, t, rank, shard_tensor_list(cfg, shard))
            written.append(f"weights_t{t}_rank{rank}.bin")

    meta = {
        "model": "tiny-llama",
        "vocab": cfg.vocab,
        "hidden": cfg.hidden,
        "intermediate": cfg.intermediate,
        "layers": cfg.layers,
        "heads": cfg.heads,
        "head_dim": cfg.head_dim,
        "max_seq": cfg.max_seq,
        "prefill_len": sp,
        "tp_degrees": tp_degrees,
        "seed": seed,
        "dtype": "f32",
        "artifacts": written,
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    # key=value twin for the Rust loader.
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        for key in ("model", "vocab", "hidden", "intermediate", "layers", "heads",
                    "head_dim", "max_seq", "prefill_len", "seed", "dtype"):
            f.write(f"{key}={meta[key]}\n")
        f.write("tp_degrees=" + ",".join(str(t) for t in tp_degrees) + "\n")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tp-degrees", default="1,2,4")
    ap.add_argument("--sp", type=int, default=32, help="prefill sequence length")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    degrees = [int(x) for x in args.tp_degrees.split(",")]
    written = build(args.out_dir, degrees, args.sp, args.seed)
    print(f"wrote {len(written)} artifacts + meta.json to {args.out_dir}")


if __name__ == "__main__":
    main()
