"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO).

Public surface:
  attention.decode_attention   — flash-decoding step over the KV cache
  attention.prefill_attention  — causal flash attention over the prompt
  swiglu.swiglu                — fused SwiGLU MLP activation
  swiglu.matmul_f32            — tiled accumulation matmul building block
  rmsnorm.rmsnorm              — fused RMSNorm
  ref.*                        — pure-jnp oracles for all of the above
"""

from . import attention, ref, rmsnorm, swiglu  # noqa: F401
