"""Pallas attention kernels — the decode/prefill hot spots of LLM inference.

The paper (§II.A, §V) identifies the autoregressive *decode* stage as the
dominant phase of distributed inference: one token per step, attention over
the whole KV cache, repeated Sd times. ``decode_attention`` implements that
step as a flash-decoding style Pallas kernel; ``prefill_attention``
implements the causal prompt pass with q-block × kv-block tiling.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's testbed is
H100 + CUDA; on TPU the same insight — keep the KV tile resident in fast
memory and stream blocks through the systolic array — maps to VMEM-sized
``BlockSpec`` tiles and MXU-friendly [block, d] GEMM shapes instead of
warp-level WMMA. Kernels are lowered with ``interpret=True`` so the CPU PJRT
client can execute the emitted HLO (real-TPU lowering produces Mosaic
custom-calls the CPU plugin cannot run).

Layouts match the serving engine: KV caches are ``[T, a, d]`` (time-major so
the Rust side can append a token with one contiguous write per step).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30  # finite sentinel: avoids nan from exp(-inf - -inf)


def _decode_attention_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, *, block_t: int):
    """One program per head: flash-decoding over KV blocks.

    q_ref: [1, d]; k_ref/v_ref: [T, 1, d]; o_ref: [1, d]; kvlen_ref: [1].
    """
    t_total = k_ref.shape[0]
    d = q_ref.shape[-1]
    kv_len = kvlen_ref[0]
    scale = 1.0 / math.sqrt(d)
    q = q_ref[0, :].astype(jnp.float32) * scale  # [d]

    n_blocks = t_total // block_t

    def body(i, carry):
        m, l, acc = carry
        start = i * block_t
        k = k_ref[pl.dslice(start, block_t), 0, :].astype(jnp.float32)  # [bt, d]
        v = v_ref[pl.dslice(start, block_t), 0, :].astype(jnp.float32)
        s = k @ q  # [bt]
        idx = start + jax.lax.broadcasted_iota(jnp.int32, (block_t,), 0)
        valid = idx < kv_len
        s = jnp.where(valid, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s))
        p = jnp.exp(s - m_new)
        p = jnp.where(valid, p, 0.0)  # kill exp(0)=1 leaks when block all-masked
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p)
        acc_new = acc * alpha + p @ v  # [d]
        return m_new, l_new, acc_new

    m0 = jnp.float32(_NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0, :] = (acc / l).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,  # [a, d]
    k_cache: jax.Array,  # [T, a, d]
    v_cache: jax.Array,  # [T, a, d]
    kv_len: jax.Array,  # [1] int32 — number of valid cache rows
    *,
    block_t: int = 64,
) -> jax.Array:
    """Single-token attention over the padded KV cache. Returns [a, d]."""
    t_total, a, d = k_cache.shape
    if q.shape != (a, d):
        raise ValueError(f"q shape {q.shape} != ({a}, {d})")
    block_t = min(block_t, t_total)
    if t_total % block_t != 0:
        raise ValueError(f"T={t_total} not divisible by block_t={block_t}")
    kernel = functools.partial(_decode_attention_kernel, block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(a,),
        in_specs=[
            pl.BlockSpec((1,), lambda h: (0,)),  # kv_len
            pl.BlockSpec((1, d), lambda h: (h, 0)),  # q head slice
            pl.BlockSpec((t_total, 1, d), lambda h: (0, h, 0)),  # K head slice
            pl.BlockSpec((t_total, 1, d), lambda h: (0, h, 0)),  # V head slice
        ],
        out_specs=pl.BlockSpec((1, d), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((a, d), q.dtype),
        interpret=True,
    )(kv_len, q, k_cache, v_cache)


def _prefill_attention_kernel(
    q_ref, k_ref, v_ref, o_ref, *, block_q: int, block_t: int
):
    """One program per (head, q-block): causal flash attention.

    q_ref: [block_q, 1, d]; k_ref/v_ref: [S, 1, d]; o_ref: [block_q, 1, d].
    """
    d = q_ref.shape[-1]
    qb = pl.program_id(1)
    scale = 1.0 / math.sqrt(d)
    q = q_ref[:, 0, :].astype(jnp.float32) * scale  # [bq, d]
    q_pos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,), 0)

    # Only kv blocks at or before this q block can contribute (causal).
    n_kv_blocks = (qb * block_q) // block_t + pl.cdiv(block_q, block_t)

    def body(i, carry):
        m, l, acc = carry
        start = i * block_t
        k = k_ref[pl.dslice(start, block_t), 0, :].astype(jnp.float32)  # [bt, d]
        v = v_ref[pl.dslice(start, block_t), 0, :].astype(jnp.float32)
        s = q @ k.T  # [bq, bt]
        k_pos = start + jax.lax.broadcasted_iota(jnp.int32, (block_t,), 0)
        causal = q_pos[:, None] >= k_pos[None, :]
        s = jnp.where(causal, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))  # [bq]
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(causal, p, 0.0)
        alpha = jnp.exp(m - m_new)  # [bq]
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v  # [bq, d]
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))
    o_ref[:, 0, :] = (acc / l[:, None]).astype(o_ref.dtype)


def prefill_attention(
    q: jax.Array,  # [S, a, d]
    k: jax.Array,  # [S, a, d]
    v: jax.Array,  # [S, a, d]
    *,
    block_q: int = 32,
    block_t: int = 32,
) -> jax.Array:
    """Causal self-attention over the prompt. Returns [S, a, d]."""
    s_len, a, d = q.shape
    block_q = min(block_q, s_len)
    block_t = min(block_t, s_len)
    if s_len % block_q != 0 or s_len % block_t != 0 or block_q % block_t != 0:
        raise ValueError(
            f"S={s_len} must be divisible by block_q={block_q} and block_t={block_t},"
            " and block_q by block_t (diagonal alignment)"
        )
    kernel = functools.partial(
        _prefill_attention_kernel, block_q=block_q, block_t=block_t
    )
    return pl.pallas_call(
        kernel,
        grid=(a, s_len // block_q),
        in_specs=[
            pl.BlockSpec((block_q, 1, d), lambda h, qb: (qb, h, 0)),
            pl.BlockSpec((s_len, 1, d), lambda h, qb: (0, h, 0)),
            pl.BlockSpec((s_len, 1, d), lambda h, qb: (0, h, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1, d), lambda h, qb: (qb, h, 0)),
        out_shape=jax.ShapeDtypeStruct((s_len, a, d), q.dtype),
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(
    t_total: int, a: int, d: int, *, block_t: int = 64, dtype_bytes: int = 4
) -> dict:
    """Estimated VMEM residency for one decode_attention program (one head).

    Used by DESIGN.md / EXPERIMENTS.md §Perf to reason about real-TPU block
    sizing (interpret-mode wallclock is not a TPU proxy). Per program we hold
    q [d], one K block [block_t, d], one V block [block_t, d], and the
    accumulator [d] in f32.
    """
    q_bytes = d * dtype_bytes
    kv_block_bytes = 2 * block_t * d * dtype_bytes
    acc_bytes = d * 4 + 2 * 4  # acc + (m, l) scalars
    total = q_bytes + kv_block_bytes + acc_bytes
    return {
        "per_program_bytes": total,
        "kv_stream_bytes": 2 * t_total * d * dtype_bytes,  # streamed via blocks
        "fits_16mb_vmem": total < 16 * 2**20,
    }
