"""RMSNorm Pallas kernel — the per-layer normalization on both residual
branches (every transformer block runs it twice, so it brackets every
AllReduce the paper counts).

One program per row-block: compute the row's mean-square in f32, scale, and
apply the learned weight — a single fused pass instead of the four-op jnp
graph (square, mean, rsqrt, mul). Row-blocked over S so prefill tiles VMEM;
h stays unblocked (the reduction axis must be resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [bm, h]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    o_ref[...] = (x * inv * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,  # [S, h]
    weight: jax.Array,  # [h]
    eps: float = 1e-5,
    *,
    block_m: int = 32,
) -> jax.Array:
    """Fused RMSNorm over the last axis. Returns [S, h]."""
    s_len, h = x.shape
    if weight.shape != (h,):
        raise ValueError(f"weight shape {weight.shape} != ({h},)")
    block_m = min(block_m, s_len)
    while s_len % block_m:
        block_m -= 1
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(s_len // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s_len, h), x.dtype),
        interpret=True,
    )(x, weight)


def vmem_footprint_bytes(h: int, *, block_m: int = 32, dtype_bytes: int = 4) -> dict:
    """VMEM residency of one rmsnorm program tile (perf-analysis helper)."""
    total = block_m * h * dtype_bytes * 2 + h * dtype_bytes
    return {"per_program_bytes": total, "fits_16mb_vmem": total < 16 * 2**20}
