"""Fused SwiGLU Pallas kernel — the MLP half of the transformer layer.

Computes ``silu(x @ w_gate) * (x @ w_up)`` in a single pass: one program per
(row-block, column-block) tile computes both GEMM tiles and the elementwise
epilogue without materializing the two [S, f] intermediates in HBM. On real
TPU hardware this halves the HBM round-trips of the naive three-op graph;
under ``interpret=True`` we keep the identical structure for correctness.

The K dimension (hidden size h) is kept unblocked: serving-scale h (2k-8k)
times a [bm, bn] tile comfortably fits VMEM (see ``vmem_footprint_bytes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wu_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [bm, h]
    g = x @ wg_ref[...].astype(jnp.float32)  # [bm, bn]
    u = x @ wu_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.lax.logistic(g) * u).astype(o_ref.dtype)


def swiglu(
    x: jax.Array,  # [S, h]
    w_gate: jax.Array,  # [h, f]
    w_up: jax.Array,  # [h, f]
    *,
    block_m: int = 32,
    block_n: int = 128,
) -> jax.Array:
    """Fused silu(x@w_gate) * (x@w_up). Returns [S, f]."""
    s_len, h = x.shape
    f = w_gate.shape[1]
    if w_gate.shape != (h, f) or w_up.shape != (h, f):
        raise ValueError(f"weight shapes {w_gate.shape}/{w_up.shape} != ({h},{f})")
    block_m = min(block_m, s_len)
    block_n = min(block_n, f)
    if s_len % block_m != 0 or f % block_n != 0:
        raise ValueError(
            f"S={s_len} %% block_m={block_m} or f={f} %% block_n={block_n} != 0"
        )
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(s_len // block_m, f // block_n),
        in_specs=[
            pl.BlockSpec((block_m, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((h, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s_len, f), x.dtype),
        interpret=True,
    )(x, w_gate, w_up)


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...].astype(jnp.float32) @ w_ref[...].astype(jnp.float32)


def matmul_f32(
    x: jax.Array,  # [M, K]
    w: jax.Array,  # [K, N]
    *,
    block_m: int = 32,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """Tiled matmul with K-loop accumulation in the output tile (f32 out).

    Building block for the projection GEMMs; grid order puts K innermost so
    the output tile stays resident while K blocks stream through — the
    MXU-friendly schedule on real hardware.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims {k} != {k2}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(f"shape ({m},{k},{n}) not divisible by blocks")
    n_k = k // block_k
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kb: (i, kb)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kb: (kb, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kb: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def vmem_footprint_bytes(
    h: int, f: int, *, block_m: int = 32, block_n: int = 128, dtype_bytes: int = 4
) -> dict:
    """VMEM residency of one swiglu program tile (perf-analysis helper)."""
    x_bytes = block_m * h * dtype_bytes
    w_bytes = 2 * h * block_n * dtype_bytes
    o_bytes = block_m * block_n * dtype_bytes
    total = x_bytes + w_bytes + o_bytes
    return {
        "per_program_bytes": total,
        "fits_16mb_vmem": total < 16 * 2**20,
        "mxu_tile_aligned": block_n % 128 == 0,
    }
