"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float accumulation order)
counterpart here. pytest + hypothesis sweep shapes/dtypes and assert
allclose between the Pallas implementation (interpret=True) and these
references. These functions are also reused by ``model_ref.py`` to build the
unsharded whole-model oracle that the Rust integration tests compare
against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm over the last axis: x / rms(x) * weight."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    """Fused SwiGLU activation: silu(x @ w_gate) * (x @ w_up).

    x: [S, h]; w_gate, w_up: [h, f] -> out [S, f].
    """
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)


def decode_attention_ref(
    q: jax.Array,  # [a, d]   single-token query, a heads, head dim d
    k_cache: jax.Array,  # [T, a, d] (T = max seq len, zero-padded past kv_len)
    v_cache: jax.Array,  # [T, a, d]
    kv_len: jax.Array | int,  # number of valid cache entries (<= T)
) -> jax.Array:
    """Single-token attention over a (padded) KV cache with length masking."""
    T = k_cache.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = (
        jnp.einsum("ad,tad->at", q.astype(jnp.float32), k_cache.astype(jnp.float32))
        * scale
    )  # [a, T]
    mask = jnp.arange(T) < kv_len  # [T]
    scores = jnp.where(mask[None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("at,tad->ad", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def prefill_attention_ref(
    q: jax.Array,  # [S, a, d]
    k: jax.Array,  # [S, a, d]
    v: jax.Array,  # [S, a, d]
) -> jax.Array:
    """Causal self-attention over the prompt (prefill phase)."""
    S = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = (
        jnp.einsum("sad,tad->ast", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )  # [a, S, S]
    causal = jnp.tril(jnp.ones((S, S), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("ast,tad->sad", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled-matmul oracle: x [M, K] @ w [K, N] -> [M, N]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
