"""Cross-language numeric pin: the greedy token trajectory of the tiny
model for a fixed prompt, asserted identically here and in
rust/tests/integration_numeric.rs. If either side drifts (weights, RoPE,
kernel numerics, sharding), this pins down which layer moved.
"""

import jax.numpy as jnp
import numpy as np

from compile import model as M

CFG = M.TINY
# Same constants as rust/tests/integration_numeric.rs.
PROMPT = [(7 * i) % CFG.vocab for i in range(32)]
EXPECTED = [95, 497, 497, 497, 109, 379, 109, 291, 497, 497, 109, 269]


def _greedy(n):
    w = M.init_weights(CFG, 0)
    kc = jnp.zeros((CFG.layers, CFG.max_seq, CFG.heads, CFG.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    logits, kc, vc = M.full_step(
        CFG, jnp.array(PROMPT, jnp.int32), jnp.zeros((1,), jnp.int32), kc, vc, w
    )
    out = [int(jnp.argmax(logits))]
    for i in range(1, n):
        pos = jnp.array([len(PROMPT) + i - 1], jnp.int32)
        logits, kc, vc = M.full_step(
            CFG, jnp.array([out[-1]], jnp.int32), pos, kc, vc, w
        )
        out.append(int(jnp.argmax(logits)))
    return out


def test_greedy_trajectory_matches_pin():
    assert _greedy(len(EXPECTED)) == EXPECTED


def test_prefix_stability():
    """Shorter generations are prefixes of longer ones (greedy + KV cache)."""
    assert _greedy(4) == EXPECTED[:4]


def test_logits_are_finite():
    w = M.init_weights(CFG, 0)
    kc = jnp.zeros((CFG.layers, CFG.max_seq, CFG.heads, CFG.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    logits, _, _ = M.full_step(
        CFG, jnp.array(PROMPT, jnp.int32), jnp.zeros((1,), jnp.int32), kc, vc, w
    )
    assert np.isfinite(np.asarray(logits)).all()
