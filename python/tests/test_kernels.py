"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; every test asserts allclose against ref.py.
This is the core correctness signal for the compute hot path that the AOT
artifacts embed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, ref, swiglu

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
BF16 = jnp.bfloat16


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    t_total=st.sampled_from([32, 64, 128, 256]),
    heads=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([16, 32, 64]),
    block_t=st.sampled_from([16, 32, 64]),
    kv_frac=st.floats(min_value=0.01, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_decode_attention_matches_ref(t_total, heads, d, block_t, kv_frac, seed):
    if t_total % block_t:
        block_t = t_total
    rng = np.random.default_rng(seed)
    kv_len = max(1, int(kv_frac * t_total))
    q = _rand(rng, (heads, d), F32)
    k = _rand(rng, (t_total, heads, d), F32)
    v = _rand(rng, (t_total, heads, d), F32)
    out = attention.decode_attention(
        q, k, v, jnp.array([kv_len], jnp.int32), block_t=block_t
    )
    exp = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(out, exp, **_tol(F32))


@pytest.mark.parametrize("dtype", [F32, BF16])
def test_decode_attention_dtypes(dtype):
    rng = np.random.default_rng(0)
    q = _rand(rng, (4, 32), dtype)
    k = _rand(rng, (64, 4, 32), dtype)
    v = _rand(rng, (64, 4, 32), dtype)
    out = attention.decode_attention(q, k, v, jnp.array([40], jnp.int32))
    assert out.dtype == dtype
    exp = ref.decode_attention_ref(q, k, v, 40)
    np.testing.assert_allclose(
        out.astype(F32), exp.astype(F32), **_tol(dtype)
    )


def test_decode_attention_kv_len_one():
    """Degenerate cache: attends solely to the first entry -> returns v[0]."""
    rng = np.random.default_rng(3)
    q = _rand(rng, (2, 16), F32)
    k = _rand(rng, (32, 2, 16), F32)
    v = _rand(rng, (32, 2, 16), F32)
    out = attention.decode_attention(q, k, v, jnp.array([1], jnp.int32), block_t=16)
    np.testing.assert_allclose(out, v[0], rtol=1e-6, atol=1e-6)


def test_decode_attention_ignores_padding():
    """Garbage beyond kv_len must not affect the output."""
    rng = np.random.default_rng(4)
    q = _rand(rng, (2, 16), F32)
    k = _rand(rng, (64, 2, 16), F32)
    v = _rand(rng, (64, 2, 16), F32)
    out1 = attention.decode_attention(q, k, v, jnp.array([10], jnp.int32), block_t=16)
    k2 = k.at[10:].set(1e6)
    v2 = v.at[10:].set(-1e6)
    out2 = attention.decode_attention(q, k2, v2, jnp.array([10], jnp.int32), block_t=16)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_attention_shape_validation():
    q = jnp.zeros((3, 16), F32)  # heads mismatch vs cache
    k = jnp.zeros((32, 2, 16), F32)
    with pytest.raises(ValueError):
        attention.decode_attention(q, k, k, jnp.array([1], jnp.int32))


# ---------------------------------------------------------------------------
# prefill_attention
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    s_len=st.sampled_from([16, 32, 64, 128]),
    heads=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_prefill_attention_matches_ref(s_len, heads, d, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (s_len, heads, d), F32)
    k = _rand(rng, (s_len, heads, d), F32)
    v = _rand(rng, (s_len, heads, d), F32)
    bq = min(32, s_len)
    out = attention.prefill_attention(q, k, v, block_q=bq, block_t=min(16, bq))
    exp = ref.prefill_attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, **_tol(F32))


def test_prefill_attention_is_causal():
    """Changing future K/V must not change earlier rows."""
    rng = np.random.default_rng(5)
    s = 32
    q = _rand(rng, (s, 2, 16), F32)
    k = _rand(rng, (s, 2, 16), F32)
    v = _rand(rng, (s, 2, 16), F32)
    out1 = attention.prefill_attention(q, k, v, block_q=16, block_t=16)
    k2 = k.at[s // 2 :].set(1e3)
    v2 = v.at[s // 2 :].set(-1e3)
    out2 = attention.prefill_attention(q, k2, v2, block_q=16, block_t=16)
    np.testing.assert_allclose(out1[: s // 2], out2[: s // 2], rtol=1e-5, atol=1e-5)


def test_prefill_attention_first_token_is_v0():
    rng = np.random.default_rng(6)
    q = _rand(rng, (16, 2, 16), F32)
    k = _rand(rng, (16, 2, 16), F32)
    v = _rand(rng, (16, 2, 16), F32)
    out = attention.prefill_attention(q, k, v, block_q=16, block_t=16)
    np.testing.assert_allclose(out[0], v[0], rtol=1e-6, atol=1e-6)


def test_prefill_block_validation():
    q = jnp.zeros((48, 2, 16), F32)
    with pytest.raises(ValueError):
        attention.prefill_attention(q, q, q, block_q=32, block_t=32)  # 48 % 32


# ---------------------------------------------------------------------------
# swiglu / matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    s_len=st.sampled_from([8, 16, 32, 64]),
    h=st.sampled_from([32, 64, 128]),
    f=st.sampled_from([128, 256, 384]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_swiglu_matches_ref(s_len, h, f, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (s_len, h), F32)
    wg = _rand(rng, (h, f), F32) * 0.1
    wu = _rand(rng, (h, f), F32) * 0.1
    out = swiglu.swiglu(x, wg, wu, block_m=min(16, s_len), block_n=min(128, f))
    exp = ref.swiglu_ref(x, wg, wu)
    np.testing.assert_allclose(out, exp, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [F32, BF16])
def test_swiglu_dtypes(dtype):
    rng = np.random.default_rng(1)
    x = _rand(rng, (16, 64), dtype)
    wg = _rand(rng, (64, 128), dtype)
    wu = _rand(rng, (64, 128), dtype)
    out = swiglu.swiglu(x, wg, wu)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(F32), ref.swiglu_ref(x, wg, wu).astype(F32), **_tol(dtype)
    )


def test_swiglu_zero_gate_is_zero():
    x = jnp.ones((8, 32), F32)
    wg = jnp.zeros((32, 128), F32)
    wu = jnp.ones((32, 128), F32)
    out = swiglu.swiglu(x, wg, wu)
    np.testing.assert_allclose(out, jnp.zeros((8, 128)), atol=1e-7)


def test_swiglu_shape_validation():
    x = jnp.zeros((8, 32), F32)
    with pytest.raises(ValueError):
        swiglu.swiglu(x, jnp.zeros((32, 100), F32), jnp.zeros((32, 100), F32),
                      block_n=64)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([16, 32, 64]),
    k=st.sampled_from([64, 128, 256]),
    n=st.sampled_from([128, 256]),
    block_k=st.sampled_from([32, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_matmul_matches_ref(m, k, n, block_k, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), F32)
    w = _rand(rng, (k, n), F32)
    out = swiglu.matmul_f32(x, w, block_m=min(16, m), block_n=128, block_k=block_k)
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=2e-4, atol=2e-4)


def test_matmul_inner_dim_validation():
    with pytest.raises(ValueError):
        swiglu.matmul_f32(jnp.zeros((8, 32), F32), jnp.zeros((64, 128), F32))


# ---------------------------------------------------------------------------
# perf-analysis helpers
# ---------------------------------------------------------------------------


def test_vmem_footprints_fit():
    att = attention.vmem_footprint_bytes(4096, 32, 128, block_t=128, dtype_bytes=2)
    assert att["fits_16mb_vmem"]
    mlp = swiglu.vmem_footprint_bytes(4096, 14336, block_m=32, block_n=128,
                                      dtype_bytes=2)
    assert mlp["fits_16mb_vmem"] and mlp["mxu_tile_aligned"]


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

from compile.kernels import rmsnorm  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    s_len=st.integers(min_value=1, max_value=64),
    h=st.sampled_from([32, 64, 256, 512]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_rmsnorm_matches_ref(s_len, h, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (s_len, h), F32)
    w = _rand(rng, (h,), F32)
    out = rmsnorm.rmsnorm(x, w)
    np.testing.assert_allclose(out, ref.rmsnorm_ref(x, w), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [F32, BF16])
def test_rmsnorm_dtypes(dtype):
    rng = np.random.default_rng(2)
    x = _rand(rng, (8, 64), dtype)
    w = _rand(rng, (64,), dtype)
    out = rmsnorm.rmsnorm(x, w)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(F32), ref.rmsnorm_ref(x, w).astype(F32), **_tol(dtype)
    )


def test_rmsnorm_unit_weight_normalizes():
    """With unit weight, output rows have RMS ~= 1."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (16, 128), F32) * 5.0
    out = rmsnorm.rmsnorm(x, jnp.ones((128,), F32))
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(16), rtol=1e-4)


def test_rmsnorm_rejects_bad_weight_shape():
    with pytest.raises(ValueError):
        rmsnorm.rmsnorm(jnp.zeros((4, 32), F32), jnp.zeros((16,), F32))


def test_rmsnorm_vmem_estimate():
    est = rmsnorm.vmem_footprint_bytes(8192, block_m=32, dtype_bytes=2)
    assert est["fits_16mb_vmem"]
