"""AOT pipeline: HLO-text emission, weight shard blobs, manifest integrity."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.TINY


def test_to_hlo_text_smoke():
    import jax

    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot(" in text or "dot " in text


def test_segment_specs_cover_all_segments():
    specs = aot.segment_specs(CFG, 2, 32)
    assert set(specs) == {"embed", "attn", "mlp", "logits"}
    # attn expects 9 params in the canonical runtime order
    assert len(specs["attn"][1]) == 9
    assert len(specs["mlp"][1]) == 5


def test_full_specs_param_count():
    specs = aot.full_specs(CFG, 32)
    assert len(specs) == 7 + 9 * CFG.layers


def test_shard_tensor_list_order_and_count():
    w = M.init_weights(CFG, 0)
    shard = M.shard_weights(CFG, w, 2, 0)
    tensors = aot.shard_tensor_list(CFG, shard)
    assert tensors[0][0] == "embed"
    assert tensors[1][0] == "final_norm"
    assert tensors[2][0] == "lm_head"
    assert len(tensors) == 3 + 9 * CFG.layers
    assert tensors[3][0] == "layer0.attn_norm"
    assert tensors[-1][0] == f"layer{CFG.layers - 1}.w_down"


def test_write_shard_roundtrip(tmp_path):
    w = M.init_weights(CFG, 0)
    shard = M.shard_weights(CFG, w, 2, 1)
    tensors = aot.shard_tensor_list(CFG, shard)
    aot.write_shard(str(tmp_path), 2, 1, tensors)
    manifest = json.load(open(tmp_path / "weights_t2_rank1.json"))
    blob = open(tmp_path / "weights_t2_rank1.bin", "rb").read()
    assert manifest["total_bytes"] == len(blob)
    for entry, (name, arr) in zip(manifest["tensors"], tensors):
        assert entry["name"] == name
        assert entry["shape"] == list(arr.shape)
        n = int(np.prod(arr.shape)) * 4
        got = np.frombuffer(blob[entry["offset"] : entry["offset"] + n], np.float32)
        np.testing.assert_array_equal(got, np.asarray(arr).ravel())


def test_full_step_flat_matches_dict_weights():
    import jax

    w = M.init_weights(CFG, 0)
    flat = [w["embed"], w["final_norm"], w["lm_head"]]
    for lw in w["layers"]:
        flat += [lw[k] for k in (
            "attn_norm", "wq", "wk", "wv", "wo", "mlp_norm",
            "w_gate", "w_up", "w_down",
        )]
    tokens = jnp.arange(8, dtype=jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    T = CFG.max_seq
    kc = jnp.zeros((CFG.layers, T, CFG.heads, CFG.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    ref_logits, _, _ = M.full_step(CFG, tokens, pos, kc, vc, w)
    flat_logits, _, _ = aot.full_step_flat(CFG, tokens, pos, kc, vc, *flat)
    np.testing.assert_array_equal(ref_logits, flat_logits)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/meta.json")),
    reason="artifacts not built",
)
def test_built_artifacts_inventory():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    meta = json.load(open(os.path.join(root, "meta.json")))
    assert meta["hidden"] == CFG.hidden and meta["layers"] == CFG.layers
    for name in meta["artifacts"]:
        path = os.path.join(root, name)
        assert os.path.exists(path), name
        if name.endswith(".hlo.txt"):
            head = open(path).read(200)
            assert "HloModule" in head
    for t in meta["tp_degrees"]:
        for r in range(t):
            assert os.path.exists(os.path.join(root, f"weights_t{t}_rank{r}.bin"))
