"""L2 correctness: TP-sharded segment composition equals the unsharded model.

Emulates in Python exactly what the Rust engine does with the AOT segment
executables (AllReduce = sum of partials, Gather = concat of slices); any
mismatch here would reproduce as wrong logits in the served model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, seed=0)


def _fresh_caches():
    T = CFG.max_seq
    kc = jnp.zeros((CFG.layers, T, CFG.heads, CFG.head_dim), jnp.float32)
    return kc, jnp.zeros_like(kc)


def _tp_forward(t, tokens, pos, kcaches, vcaches, shards):
    """Mirror of the Rust engine loop: segments + summed AllReduce + Gather."""
    x = sum(
        M.embed_partial(
            CFG, t, tokens, shards[r]["embed"],
            jnp.array([r * CFG.vocab // t], jnp.int32),
        )
        for r in range(t)
    )
    for l in range(CFG.layers):
        parts = []
        for r in range(t):
            lw = shards[r]["layers"][l]
            pa, k2, v2 = M.attn_partial(
                CFG, t, x, kcaches[r][l], vcaches[r][l], pos,
                lw["attn_norm"], lw["wq"], lw["wk"], lw["wv"], lw["wo"],
            )
            parts.append(pa)
            kcaches[r][l] = k2
            vcaches[r][l] = v2
        x = x + sum(parts)  # AllReduce #1
        pm = sum(
            M.mlp_partial(
                CFG, t, x,
                shards[r]["layers"][l]["mlp_norm"],
                shards[r]["layers"][l]["w_gate"],
                shards[r]["layers"][l]["w_up"],
                shards[r]["layers"][l]["w_down"],
            )
            for r in range(t)
        )
        x = x + pm  # AllReduce #2
    return jnp.concatenate(  # Gather
        [
            M.logits_partial(CFG, t, x, shards[r]["final_norm"], shards[r]["lm_head"])
            for r in range(t)
        ],
        axis=-1,
    )


@pytest.mark.parametrize("t", [2, 4])
def test_tp_prefill_and_decode_match_reference(weights, t):
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, 16), jnp.int32)
    pos0 = jnp.zeros((1,), jnp.int32)
    kc, vc = _fresh_caches()
    logits_ref, kc1, vc1 = M.full_step(CFG, tokens, pos0, kc, vc, weights)

    shards = [M.shard_weights(CFG, weights, t, r) for r in range(t)]
    aL = CFG.heads // t
    T = CFG.max_seq
    kc_sh = [
        [jnp.zeros((T, aL, CFG.head_dim), jnp.float32) for _ in range(CFG.layers)]
        for _ in range(t)
    ]
    vc_sh = [
        [jnp.zeros((T, aL, CFG.head_dim), jnp.float32) for _ in range(CFG.layers)]
        for _ in range(t)
    ]
    logits_tp = _tp_forward(t, tokens, pos0, kc_sh, vc_sh, shards)
    np.testing.assert_allclose(logits_tp, logits_ref, rtol=1e-4, atol=1e-4)

    # Greedy decode continues identically through the sharded KV caches.
    tok = jnp.array([int(jnp.argmax(logits_ref))], jnp.int32)
    pos = jnp.array([16], jnp.int32)
    logits_ref2, _, _ = M.full_step(CFG, tok, pos, kc1, vc1, weights)
    logits_tp2 = _tp_forward(t, tok, pos, kc_sh, vc_sh, shards)
    np.testing.assert_allclose(logits_tp2, logits_ref2, rtol=1e-4, atol=1e-4)
    assert int(jnp.argmax(logits_tp2)) == int(jnp.argmax(logits_ref2))


def test_embed_partials_sum_to_full_embedding(weights):
    rng = np.random.default_rng(11)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, 8), jnp.int32)
    full = weights["embed"][tokens]
    for t in (2, 4):
        shards = [M.shard_weights(CFG, weights, t, r) for r in range(t)]
        total = sum(
            M.embed_partial(
                CFG, t, tokens, shards[r]["embed"],
                jnp.array([r * CFG.vocab // t], jnp.int32),
            )
            for r in range(t)
        )
        np.testing.assert_allclose(total, full, rtol=1e-6, atol=1e-6)


def test_embed_partial_disjoint_support(weights):
    """Each token is embedded by exactly one rank (vocab-parallel rows)."""
    t = 4
    tokens = jnp.asarray([0, CFG.vocab // 4, CFG.vocab // 2, CFG.vocab - 1], jnp.int32)
    shards = [M.shard_weights(CFG, weights, t, r) for r in range(t)]
    nonzero_owners = np.zeros((t, len(tokens)), dtype=bool)
    for r in range(t):
        part = M.embed_partial(
            CFG, t, tokens, shards[r]["embed"],
            jnp.array([r * CFG.vocab // t], jnp.int32),
        )
        nonzero_owners[r] = np.any(np.asarray(part) != 0.0, axis=-1)
    assert (nonzero_owners.sum(axis=0) == 1).all()


def test_shard_weights_partition_is_exact(weights):
    """Column/row shards reassemble to the original tensors."""
    for t in (2, 4):
        shards = [M.shard_weights(CFG, weights, t, r) for r in range(t)]
        lm = jnp.concatenate([s["lm_head"] for s in shards], axis=1)
        np.testing.assert_array_equal(lm, weights["lm_head"])
        emb = jnp.concatenate([s["embed"] for s in shards], axis=0)
        np.testing.assert_array_equal(emb, weights["embed"])
        wo = jnp.concatenate([s["layers"][0]["wo"] for s in shards], axis=0)
        np.testing.assert_array_equal(wo, weights["layers"][0]["wo"])
        wg = jnp.concatenate([s["layers"][0]["w_gate"] for s in shards], axis=1)
        np.testing.assert_array_equal(wg, weights["layers"][0]["w_gate"])


def test_validate_tp_rejects_bad_degrees():
    with pytest.raises(ValueError):
        CFG.validate_tp(3)
    with pytest.raises(ValueError):
        CFG.validate_tp(CFG.heads * 2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**16))
def test_greedy_decode_is_deterministic(seed):
    """Same prompt -> same token trajectory (the engine relies on argmax
    determinism for its cross-layout equivalence checks)."""
    w = M.init_weights(CFG, seed=0)
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, 8), jnp.int32)
    kc, vc = _fresh_caches()
    out1, kc, vc = M.full_step(CFG, tokens, jnp.zeros((1,), jnp.int32), kc, vc, w)
    kc2, vc2 = _fresh_caches()
    out2, _, _ = M.full_step(CFG, tokens, jnp.zeros((1,), jnp.int32), kc2, vc2, w)
    assert int(jnp.argmax(out1)) == int(jnp.argmax(out2))
    np.testing.assert_array_equal(out1, out2)


def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 4, 32)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)
    y = M.rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-5
    )


def test_rope_position_zero_is_identity():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 4, 32)), jnp.float32)
    y = M.rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
    np.testing.assert_allclose(y, x, rtol=1e-6, atol=1e-6)
