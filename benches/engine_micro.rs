//! Microbenchmark: end-to-end engine throughput.
//!
//! - structural mode: coordinator + collectives overhead at paper scale
//!   (the communication skeleton without compute);
//! - numeric mode (if artifacts are built): the tiny real model through
//!   PJRT — the serve_e2e hot path the §Perf pass optimizes.

use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::runtime::ArtifactStore;
use commsim::testutil::bench;

fn main() -> anyhow::Result<()> {
    println!("engine microbenchmarks\n");

    // Structural skeleton at 8B scale. The request total is dominated by
    // prefill buffer churn ([128, 4096] AllReduces); decode-step cost is
    // reported from the engine's own per-step latencies.
    for (tp, pp) in [(2usize, 1usize), (4, 1), (1, 2), (2, 2)] {
        let plan = Deployment::builder()
            .arch(ModelArch::llama31_8b())
            .tp(tp)
            .pp(pp)
            .build()?;
        let mut engine = plan.engine()?;
        let mut last_tpot = std::time::Duration::ZERO;
        let stats = bench(
            &format!("structural 8B tp={tp} pp={pp} (Sp=128, Sd=16)"),
            1,
            5,
            || {
                let r = engine.generate(&[0i32; 128], 16).unwrap();
                last_tpot = r.tpot;
            },
        );
        println!("{}  -> decode step {last_tpot:?}", stats.report());
        engine.trace().clear();
    }

    // Numeric tiny model (needs `make artifacts`).
    match ArtifactStore::open(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(store) => {
            let sp = store.meta.prefill_len;
            let prompt: Vec<i32> = (0..sp as i32).collect();
            for (tp, pp) in [(1usize, 1usize), (2, 1), (2, 2)] {
                let plan = Deployment::builder()
                    .artifacts(store.clone())
                    .tp(tp)
                    .pp(pp)
                    .build()?;
                let mut engine = plan.engine()?;
                engine.warmup()?;
                let stats = bench(
                    &format!("numeric tiny tp={tp} pp={pp} (Sp={sp}, Sd=16)"),
                    1,
                    5,
                    || {
                        engine.generate(&prompt, 16).unwrap();
                    },
                );
                let tokens_per_s = 16.0 / stats.mean.as_secs_f64();
                println!("{}  -> {tokens_per_s:.1} tok/s", stats.report());
            }

            // Fused single-dispatch fast path vs the segment loop (t=1).
            let mut fused = commsim::engine::fused::FusedEngine::new(store.clone())?;
            fused.generate(&prompt, 2)?; // warmup
            let stats = bench(
                &format!("numeric tiny FUSED t=1 (Sp={sp}, Sd=16)"),
                1,
                5,
                || {
                    fused.generate(&prompt, 16).unwrap();
                },
            );
            let tokens_per_s = 16.0 / stats.mean.as_secs_f64();
            println!("{}  -> {tokens_per_s:.1} tok/s", stats.report());
        }
        Err(e) => println!("(numeric benches skipped: {e})"),
    }
    Ok(())
}
