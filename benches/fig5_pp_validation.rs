//! Figure 5 reproduction: pipeline parallelism — analytical model validated
//! against observed data (E2E point-to-point count & total message size),
//! Llama-3.1-8B, across PP degrees.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_bytes, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut failures = 0;

    for pp in [2usize, 4, 8] {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .pp(pp)
            .workload(128, 128)
            .build()?;
        let shape = plan.shape();
        // Fig. 5 uses the global view (each transfer counted once).
        let predicted = plan.analyze();
        let s = plan.trace()?;

        let mut a_count = 0usize;
        let mut a_bytes = 0f64;
        let mut m_count = 0usize;
        let mut m_bytes = 0usize;
        for stage in [Stage::Prefill, Stage::Decode] {
            for o in predicted
                .global_ops(stage)
                .ops
                .iter()
                .filter(|o| o.op == CollectiveKind::Send)
            {
                let elems: usize = o.shape.iter().product();
                a_count += o.count;
                a_bytes += (o.count * elems * shape.dtype_bytes) as f64;
            }
            // Global sends (each transfer counted once, like the paper).
            for (k, v) in s.global.iter().filter(|(k, _)| {
                k.op == CollectiveKind::Send && k.stage == stage
            }) {
                let _ = k;
                m_count += v.count;
                m_bytes += v.total_message_bytes;
            }
        }
        let ok = a_count == m_count && (a_bytes - m_bytes as f64).abs() < 0.5;
        if !ok {
            failures += 1;
        }
        series.push((pp, a_count, m_count, a_bytes, m_bytes));
        rows.push(vec![
            format!("PP={pp}"),
            a_count.to_string(),
            m_count.to_string(),
            fmt_bytes(a_bytes),
            fmt_bytes(m_bytes as f64),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            "Fig. 5 — PP validation: E2E p2p count & total message size (Llama-3.1-8B)",
            &[
                "Degree",
                "Count (model)",
                "Count (observed)",
                "Bytes (model)",
                "Bytes (observed)",
                "",
            ],
            &rows,
        )
    );
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig5_pp_validation");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (pp, a_count, m_count, a_bytes, m_bytes) in &series {
            j.row(&[
                ("pp", JsonValue::from(*pp)),
                ("analytic_count", JsonValue::from(*a_count)),
                ("measured_count", JsonValue::from(*m_count)),
                ("analytic_bytes", JsonValue::from(*a_bytes)),
                ("measured_bytes", JsonValue::from(*m_bytes)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} degrees diverged");
    }
    println!("\nFig. 5 reproduced: analytical model matches observation exactly for all degrees.");
    Ok(())
}
