//! Table IV reproduction: AllReduce message size and count across
//! Llama-3.2-3B / Llama-3.1-8B / Llama-2-13B for end-to-end inference
//! (Sp = Sd = 128, BF16, TP=4).

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    // Paper Table IV: (model, prefill msg bytes, decode msg bytes,
    //                  prefill count, decode count).
    let paper = [
        (ModelArch::llama32_3b(), 786_432usize, 6_144usize, 57usize, 7_239usize),
        (ModelArch::llama31_8b(), 1_048_576, 8_192, 65, 8_255),
        (ModelArch::llama2_13b(), 1_310_720, 10_240, 81, 10_287),
    ];

    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut failures = 0;
    for (arch, p_pre_bytes, p_dec_bytes, p_pre_count, p_dec_count) in paper {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(4)
            .workload(128, 128)
            .build()?;
        let s = plan.trace()?;
        let pre = s.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
        let dec = s.paper_view(CollectiveKind::AllReduce, Stage::Decode);
        let m_pre_bytes = pre.total_message_bytes / pre.count.max(1);
        let m_dec_bytes = dec.total_message_bytes / dec.count.max(1);
        let ok = pre.count == p_pre_count
            && dec.count == p_dec_count
            && m_pre_bytes == p_pre_bytes
            && m_dec_bytes == p_dec_bytes;
        if !ok {
            failures += 1;
        }
        series.push((
            arch.name.clone(),
            m_pre_bytes,
            m_dec_bytes,
            pre.count,
            dec.count,
            pre.modeled_time_s,
            dec.modeled_time_s,
        ));
        rows.push(vec![
            arch.name.clone(),
            format!("{p_pre_bytes} / {p_dec_bytes}"),
            format!("{m_pre_bytes} / {m_dec_bytes}"),
            format!("{p_pre_count} / {p_dec_count}"),
            format!("{} / {}", pre.count, dec.count),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            "Table IV — AllReduce message size & count across models (prefill / decode)",
            &["Model", "Paper bytes", "Measured bytes", "Paper count", "Measured count", ""],
            &rows,
        )
    );
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("table4_allreduce_models");
        j.param("tp", 4usize).param("sp", 128usize).param("sd", 128usize);
        for (model, pre_b, dec_b, pre_c, dec_c, pre_s, dec_s) in &series {
            j.row(&[
                ("model", JsonValue::from(model.as_str())),
                ("prefill_msg_bytes", JsonValue::from(*pre_b)),
                ("decode_msg_bytes", JsonValue::from(*dec_b)),
                ("prefill_count", JsonValue::from(*pre_c)),
                ("decode_count", JsonValue::from(*dec_c)),
                ("prefill_modeled_s", JsonValue::from(*pre_s)),
                ("decode_modeled_s", JsonValue::from(*dec_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} models mismatched the paper");
    }
    println!("\nTable IV fully reproduced (byte-exact message sizes, exact counts).");
    Ok(())
}
