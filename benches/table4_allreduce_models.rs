//! Table IV reproduction: AllReduce message size and count across
//! Llama-3.2-3B / Llama-3.1-8B / Llama-2-13B for end-to-end inference
//! (Sp = Sd = 128, BF16, TP=4).

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::render_table;

fn main() -> anyhow::Result<()> {
    // Paper Table IV: (model, prefill msg bytes, decode msg bytes,
    //                  prefill count, decode count).
    let paper = [
        (ModelArch::llama32_3b(), 786_432usize, 6_144usize, 57usize, 7_239usize),
        (ModelArch::llama31_8b(), 1_048_576, 8_192, 65, 8_255),
        (ModelArch::llama2_13b(), 1_310_720, 10_240, 81, 10_287),
    ];

    let mut rows = Vec::new();
    let mut failures = 0;
    for (arch, p_pre_bytes, p_dec_bytes, p_pre_count, p_dec_count) in paper {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(4)
            .workload(128, 128)
            .build()?;
        let s = plan.trace()?;
        let pre = s.paper_view(CollectiveKind::AllReduce, Stage::Prefill);
        let dec = s.paper_view(CollectiveKind::AllReduce, Stage::Decode);
        let m_pre_bytes = pre.total_message_bytes / pre.count.max(1);
        let m_dec_bytes = dec.total_message_bytes / dec.count.max(1);
        let ok = pre.count == p_pre_count
            && dec.count == p_dec_count
            && m_pre_bytes == p_pre_bytes
            && m_dec_bytes == p_dec_bytes;
        if !ok {
            failures += 1;
        }
        rows.push(vec![
            arch.name.clone(),
            format!("{p_pre_bytes} / {p_dec_bytes}"),
            format!("{m_pre_bytes} / {m_dec_bytes}"),
            format!("{p_pre_count} / {p_dec_count}"),
            format!("{} / {}", pre.count, dec.count),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            "Table IV — AllReduce message size & count across models (prefill / decode)",
            &["Model", "Paper bytes", "Measured bytes", "Paper count", "Measured count", ""],
            &rows,
        )
    );
    if failures > 0 {
        anyhow::bail!("{failures} models mismatched the paper");
    }
    println!("\nTable IV fully reproduced (byte-exact message sizes, exact counts).");
    Ok(())
}
