//! Figure 4 reproduction: tensor parallelism — analytical model validated
//! against observed data (AllReduce count & total message size), TP=4,
//! end-to-end (prefill + decode), across the three evaluation models.

use commsim::analysis::{InferenceShape, OpCountModel, ParallelLayout};
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;
use commsim::report::{fmt_bytes, render_table};

fn main() -> anyhow::Result<()> {
    let layout = ParallelLayout::new(4, 1);
    let shape = InferenceShape::new(128, 128, 2);
    let mut rows = Vec::new();
    let mut failures = 0;

    for arch in ModelArch::paper_models() {
        let model = OpCountModel::new(arch.clone(), layout, shape);
        let mut engine = Engine::new(EngineConfig::structural(arch.clone(), layout))?;
        engine.generate(&vec![0i32; 128], 128)?;
        let s = engine.trace().summary();

        // E2E = prefill + decode, per-worker paper view.
        let mut a_count = 0usize;
        let mut a_bytes = 0f64;
        let mut m_count = 0usize;
        let mut m_bytes = 0usize;
        for stage in [Stage::Prefill, Stage::Decode] {
            let pred = model.predict_paper_view(stage);
            for o in pred.ops.iter().filter(|o| o.op == CollectiveKind::AllReduce) {
                let elems: usize = o.shape.iter().product();
                a_count += o.count;
                a_bytes += (o.count * elems * shape.dtype_bytes) as f64;
            }
            let obs = s.paper_view(CollectiveKind::AllReduce, stage);
            m_count += obs.count;
            m_bytes += obs.total_message_bytes;
        }
        let ok = a_count == m_count && (a_bytes - m_bytes as f64).abs() < 0.5;
        if !ok {
            failures += 1;
        }
        rows.push(vec![
            arch.name.clone(),
            a_count.to_string(),
            m_count.to_string(),
            fmt_bytes(a_bytes),
            fmt_bytes(m_bytes as f64),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            "Fig. 4 — TP=4 validation: E2E AllReduce count & total message size",
            &["Model", "Count (model)", "Count (observed)", "Bytes (model)", "Bytes (observed)", ""],
            &rows,
        )
    );
    if failures > 0 {
        anyhow::bail!("{failures} models diverged");
    }
    println!("\nFig. 4 reproduced: analytical model matches observation exactly for all models.");
    Ok(())
}
