//! Figure 4 reproduction: tensor parallelism — analytical model validated
//! against observed data (AllReduce count & total message size), TP=4,
//! end-to-end (prefill + decode), across the three evaluation models.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_bytes, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let mut rows = Vec::new();
    let mut series = Vec::new();
    let mut failures = 0;

    for arch in ModelArch::paper_models() {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(4)
            .workload(128, 128)
            .build()?;
        let predicted = plan.analyze();
        let s = plan.trace()?;
        let shape = plan.shape();

        // E2E = prefill + decode, per-worker paper view.
        let mut a_count = 0usize;
        let mut a_bytes = 0f64;
        let mut m_count = 0usize;
        let mut m_bytes = 0usize;
        for stage in [Stage::Prefill, Stage::Decode] {
            for o in predicted
                .ops(stage)
                .ops
                .iter()
                .filter(|o| o.op == CollectiveKind::AllReduce)
            {
                let elems: usize = o.shape.iter().product();
                a_count += o.count;
                a_bytes += (o.count * elems * shape.dtype_bytes) as f64;
            }
            let obs = s.paper_view(CollectiveKind::AllReduce, stage);
            m_count += obs.count;
            m_bytes += obs.total_message_bytes;
        }
        let ok = a_count == m_count && (a_bytes - m_bytes as f64).abs() < 0.5;
        if !ok {
            failures += 1;
        }
        series.push((arch.name.clone(), a_count, m_count, a_bytes, m_bytes));
        rows.push(vec![
            arch.name.clone(),
            a_count.to_string(),
            m_count.to_string(),
            fmt_bytes(a_bytes),
            fmt_bytes(m_bytes as f64),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
        // Compressed-wire variants are derived, not traced: traces keep the
        // logical bf16 volume (so the analytic-vs-observed match above is
        // wire-precision-independent) and the wire factor scales it.
        for bits in [8u32, 4] {
            let wire_bytes = a_bytes * bits as f64 / 16.0;
            rows.push(vec![
                format!("{} @int{bits} wire", arch.name),
                "".into(),
                "".into(),
                fmt_bytes(wire_bytes),
                "".into(),
                "derived".into(),
            ]);
        }
    }
    print!(
        "{}",
        render_table(
            "Fig. 4 — TP=4 validation: E2E AllReduce count & total message size",
            &[
                "Model",
                "Count (model)",
                "Count (observed)",
                "Bytes (model)",
                "Bytes (observed)",
                "",
            ],
            &rows,
        )
    );
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig4_tp_validation");
        j.param("tp", 4usize).param("sp", 128usize).param("sd", 128usize);
        for (model, a_count, m_count, a_bytes, m_bytes) in &series {
            j.row(&[
                ("model", JsonValue::from(model.as_str())),
                ("analytic_count", JsonValue::from(*a_count)),
                ("measured_count", JsonValue::from(*m_count)),
                ("analytic_bytes", JsonValue::from(*a_bytes)),
                ("measured_bytes", JsonValue::from(*m_bytes)),
                ("wire_bytes_int8", JsonValue::from(a_bytes * 0.5)),
                ("wire_bytes_int4", JsonValue::from(a_bytes * 0.25)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} models diverged");
    }
    println!("\nFig. 4 reproduced: analytical model matches observation exactly for all models.");
    Ok(())
}
