//! Figure 9 reproduction: Llama-3.2-3B SLO metrics across pipeline
//! parallelism degrees (PP=2, 4 intra-node; PP=8 across two nodes),
//! Sp = Sd = 128.

use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama32_3b();
    // Paper Fig. 9: (pp, e2e s, ttft ms, tpot ms ~).
    let paper = [
        (2usize, 0.69f64, 430.0f64, 2.0f64),
        (4, 1.36, 1110.0, 2.0),
        (8, 4.98, 2520.0, 19.22),
    ];

    let mut rows = Vec::new();
    let mut sims = Vec::new();
    for (pp, p_e2e, p_ttft, p_tpot) in paper {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .pp(pp)
            .workload(128, 128)
            .build()?;
        let r = plan.simulate();
        sims.push((pp, r));
        rows.push(vec![
            format!("PP={pp}{}", if pp == 8 { " (2 nodes)" } else { "" }),
            format!("{:.2} / {:.2}", p_e2e, r.e2e_s),
            format!("{:.0} / {:.0}", p_ttft, r.ttft_s * 1e3),
            format!("{:.2} / {:.2}", p_tpot, r.tpot_s * 1e3),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Fig. 9 — Llama-3.2-3B SLOs vs PP degree (paper / simulated)",
            &["Config", "E2E (s)", "TTFT (ms)", "TPOT (ms)"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig9_pp_slo");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (pp, r) in &sims {
            j.row(&[
                ("pp", JsonValue::from(*pp)),
                ("ttft_s", JsonValue::from(r.ttft_s)),
                ("tpot_s", JsonValue::from(r.tpot_s)),
                ("e2e_s", JsonValue::from(r.e2e_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    let r = |pp: usize| sims.iter().find(|(p, _)| *p == pp).unwrap().1;
    // Paper's qualitative findings: latency grows with pipeline depth;
    // TPOT stays ~2 ms intra-node, then jumps ~10x cross-node.
    anyhow::ensure!(r(4).ttft_s > r(2).ttft_s && r(8).ttft_s > r(4).ttft_s);
    anyhow::ensure!(r(4).e2e_s > r(2).e2e_s && r(8).e2e_s > r(4).e2e_s);
    anyhow::ensure!((r(2).tpot_s - r(4).tpot_s).abs() < 0.5e-3, "TPOT stable intra-node");
    anyhow::ensure!(r(8).tpot_s > 8.0 * r(4).tpot_s, "cross-node handoffs dominate");
    for (pp, p_e2e, p_ttft, _) in paper {
        let s = r(pp);
        anyhow::ensure!((s.e2e_s - p_e2e).abs() / p_e2e < 0.30, "PP={pp} E2E within 30%");
        anyhow::ensure!(
            (s.ttft_s * 1e3 - p_ttft).abs() / p_ttft < 0.30,
            "PP={pp} TTFT within 30%"
        );
    }
    println!(
        "\nFig. 9 reproduced: deep pipelines trade latency for comm volume; cross-node \
         TPOT spike."
    );
    Ok(())
}
