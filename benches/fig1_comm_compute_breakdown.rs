//! Figure 1 reproduction: communication/computation breakdown for
//! Llama-3.1-8B inference under various parallelism settings.
//!
//! The paper's motivating figure shows the fraction of execution time spent
//! in communication per layout. The plan facade's SLO simulator decomposes
//! every phase into {compute, comm, framework overhead} (perfmodel::slo);
//! this bench prints the same series, plus an int8-wire variant of each
//! layout (Flash-Communication-style compressed collectives) to show how
//! much of the comm share a quantized wire claws back.

use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    let layouts = [(2usize, 1usize), (4, 1), (1, 2), (1, 4), (2, 2)];
    let wire_variants = [16u32, 8];

    let mut rows = Vec::new();
    let mut fractions = Vec::new();
    let mut series = Vec::new();
    for (tp, pp) in layouts {
        for bits in wire_variants {
            let mut builder = Deployment::builder()
                .arch(arch.clone())
                .tp(tp)
                .pp(pp)
                .workload(128, 128);
            if bits != 16 {
                builder = builder.collective_tuning(bits, 0.0);
            }
            let plan = builder.build()?;
            let shape = plan.shape();
            let r = plan.simulate();
            let f = r.comm_fraction(shape);
            if bits == 16 {
                fractions.push(((tp, pp), f));
            }
            let steps = (shape.decode_len - 1) as f64;
            let compute = r.prefill.compute_s + steps * r.decode_step.compute_s;
            let comm = r.prefill.comm_s + steps * r.decode_step.comm_s;
            let overhead = r.prefill.overhead_s + steps * r.decode_step.overhead_s;
            series.push((tp, pp, bits, f, compute, comm, overhead, r.e2e_s));
            rows.push(vec![
                plan.layout().label(),
                format!("{bits}"),
                format!("{:.1}%", f * 100.0),
                format!("{:.1} ms", compute * 1e3),
                format!("{:.1} ms", comm * 1e3),
                format!("{:.1} ms", overhead * 1e3),
                format!("{:.3} s", r.e2e_s),
            ]);
        }
    }
    print!(
        "{}",
        render_table(
            "Fig. 1 — comm/compute breakdown, Llama-3.1-8B, Sp=Sd=128",
            &["Layout", "Wire bits", "Comm fraction", "Compute", "Comm", "Framework", "E2E"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig1_comm_compute_breakdown");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (tp, pp, bits, f, compute, comm, overhead, e2e) in &series {
            j.row(&[
                ("tp", JsonValue::from(*tp)),
                ("pp", JsonValue::from(*pp)),
                ("wire_bits", JsonValue::from(*bits as usize)),
                ("comm_fraction", JsonValue::from(*f)),
                ("compute_s", JsonValue::from(*compute)),
                ("comm_s", JsonValue::from(*comm)),
                ("overhead_s", JsonValue::from(*overhead)),
                ("e2e_s", JsonValue::from(*e2e)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    // Paper's qualitative claims: TP is the most communication-bound;
    // decode-stage comm dominates; PP comm fraction is the smallest.
    let f = |tp: usize, pp: usize| {
        fractions
            .iter()
            .find(|((t, p), _)| *t == tp && *p == pp)
            .map(|(_, f)| *f)
            .unwrap()
    };
    anyhow::ensure!(f(4, 1) > f(1, 4), "TP must be more comm-bound than PP");
    anyhow::ensure!(f(4, 1) > f(2, 1), "comm fraction grows with TP degree");
    // The compressed wire never costs comm time (quant/dequant priced in)
    // and never touches compute.
    for (tp, pp) in layouts {
        let pick = |bits: u32| {
            series
                .iter()
                .find(|(t, p, b, ..)| *t == tp && *p == pp && *b == bits)
                .copied()
                .unwrap()
        };
        let fp16 = pick(16);
        let int8 = pick(8);
        anyhow::ensure!(int8.5 <= fp16.5, "int8 comm exceeds fp16 at tp{tp}xpp{pp}");
        anyhow::ensure!(int8.4 == fp16.4, "wire precision moved compute at tp{tp}xpp{pp}");
    }
    println!("\nFig. 1 shape holds: TP4 comm share {:.1}% > TP2 {:.1}% > PP4 {:.1}%",
        f(4,1) * 100.0, f(2,1) * 100.0, f(1,4) * 100.0);
    Ok(())
}
