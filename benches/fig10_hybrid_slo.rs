//! Figure 10 reproduction: Llama-2-13B SLO metrics across hybrid
//! parallelism strategies on 8 GPUs / 2 nodes: TP=8, TP=4×PP=2 (the
//! paper's "catastrophic" unbalanced config), TP=2×PP=4, PP=8.

use commsim::analysis::ParallelLayout;
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama2_13b();
    // Paper Fig. 10 (numbers quoted in §V.C; '-' = not stated precisely).
    let paper: &[(usize, usize, Option<f64>, Option<f64>, Option<f64>)] = &[
        // (tp, pp, e2e s, ttft ms, tpot ms)
        (8, 1, Some(2.37), Some(70.0), Some(18.0)),
        (4, 2, Some(15.15), None, Some(103.0)),
        (2, 4, None, None, None), // "intermediate performance"
        (1, 8, None, Some(2430.0), None), // "moderate"
    ];

    let mut rows = Vec::new();
    let mut sims = Vec::new();
    for &(tp, pp, p_e2e, p_ttft, p_tpot) in paper {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(tp)
            .pp(pp)
            .workload(128, 128)
            .build()?;
        let r = plan.simulate();
        sims.push(((tp, pp), r));
        let fmt_opt = |v: Option<f64>, scale: f64, digits: usize| match v {
            Some(x) => format!("{:.*}", digits, x * scale),
            None => "-".to_string(),
        };
        rows.push(vec![
            ParallelLayout::new(tp, pp).label(),
            format!("{} / {:.2}", fmt_opt(p_e2e, 1.0, 2), r.e2e_s),
            format!("{} / {:.0}", fmt_opt(p_ttft, 1.0, 0), r.ttft_s * 1e3),
            format!("{} / {:.1}", fmt_opt(p_tpot, 1.0, 1), r.tpot_s * 1e3),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Fig. 10 — Llama-2-13B SLOs, 8 GPUs / 2 nodes (paper / simulated)",
            &["Config", "E2E (s)", "TTFT (ms)", "TPOT (ms)"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig10_hybrid_slo");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for ((tp, pp), r) in &sims {
            j.row(&[
                ("tp", JsonValue::from(*tp)),
                ("pp", JsonValue::from(*pp)),
                ("ttft_s", JsonValue::from(r.ttft_s)),
                ("tpot_s", JsonValue::from(r.tpot_s)),
                ("e2e_s", JsonValue::from(r.e2e_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    let r = |tp: usize, pp: usize| {
        sims.iter().find(|((t, p), _)| *t == tp && *p == pp).unwrap().1
    };
    // Paper's headline findings.
    anyhow::ensure!(
        r(8, 1).e2e_s < r(2, 4).e2e_s && r(8, 1).e2e_s < r(1, 8).e2e_s
            && r(8, 1).e2e_s < r(4, 2).e2e_s,
        "pure TP=8 is the best configuration"
    );
    anyhow::ensure!(
        r(4, 2).e2e_s > r(2, 4).e2e_s && r(4, 2).e2e_s > r(1, 8).e2e_s,
        "unbalanced TP=4 PP=2 is catastrophic"
    );
    anyhow::ensure!(
        r(8, 1).ttft_s < 0.1 * r(1, 8).ttft_s,
        "TP=8 TTFT advantage over PP=8 (prefill parallelization)"
    );
    // Quantitative where the paper quotes numbers (within 35%).
    let close = |got: f64, want: f64, what: &str| {
        anyhow::ensure!((got - want).abs() / want < 0.35, "{what}: {got} vs {want}");
        Ok(())
    };
    close(r(8, 1).e2e_s, 2.37, "TP8 E2E")?;
    close(r(8, 1).tpot_s * 1e3, 18.0, "TP8 TPOT")?;
    close(r(4, 2).tpot_s * 1e3, 103.0, "TP4PP2 TPOT")?;
    close(r(1, 8).ttft_s * 1e3, 2430.0, "PP8 TTFT")?;
    println!("\nFig. 10 reproduced: TP8 optimal, TP4 PP2 catastrophic, TP2 PP4 intermediate.");
    Ok(())
}
