//! Ablations of DESIGN.md's called-out choices:
//! 1. placement — TP packed within a node vs spanning nodes (why vLLM's
//!    "TP inside, PP across" default matters);
//! 2. serving dtype — BF16 vs F32 halves every message (Table I's `b`);
//! 3. collective algorithm accounting — ring vs naive star AllReduce cost.

use commsim::cluster::{NetModel, Topology};
use commsim::model::ModelArch;
use commsim::perfmodel::Calibration;
use commsim::plan::Deployment;
use commsim::report::{fmt_bytes, render_table};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama32_3b();

    // --- 1. placement: TP=4 on one node vs straddling two --------------
    // Same model, same layout, same workload — only the topology differs.
    let packed = Deployment::builder()
        .arch(arch.clone())
        .tp(4)
        .topology(Topology::new(1, 4))
        .workload(128, 128)
        .build()?;
    let straddled = Deployment::builder()
        .arch(arch.clone())
        .tp(4)
        .topology(Topology::new(2, 2))
        .workload(128, 128)
        .build()?;
    let r_packed = packed.simulate();
    let r_straddled = straddled.simulate();
    print!(
        "{}",
        render_table(
            "Ablation — TP=4 placement (Llama-3.2-3B)",
            &["Placement", "TTFT (ms)", "TPOT (ms)", "E2E (s)"],
            &[
                vec![
                    "packed (1 node × 4 GPU)".into(),
                    format!("{:.1}", r_packed.ttft_s * 1e3),
                    format!("{:.2}", r_packed.tpot_s * 1e3),
                    format!("{:.3}", r_packed.e2e_s),
                ],
                vec![
                    "straddled (2 nodes × 2 GPU)".into(),
                    format!("{:.1}", r_straddled.ttft_s * 1e3),
                    format!("{:.2}", r_straddled.tpot_s * 1e3),
                    format!("{:.3}", r_straddled.e2e_s),
                ],
            ],
        )
    );
    anyhow::ensure!(
        r_straddled.tpot_s > 5.0 * r_packed.tpot_s,
        "straddling nodes must wreck decode"
    );
    println!(
        "=> same layout, same bytes: {:.1}x TPOT penalty purely from placement.\n",
        r_straddled.tpot_s / r_packed.tpot_s
    );

    // --- 2. dtype: BF16 vs F32 -----------------------------------------
    let mut rows = Vec::new();
    for (name, b) in [("BF16", 2usize), ("F32", 4)] {
        let v = Deployment::builder()
            .arch(ModelArch::llama31_8b())
            .tp(4)
            .workload(128, 128)
            .dtype_bytes(b)
            .build()?
            .analyze();
        rows.push(vec![name.into(), fmt_bytes(v.total_bytes())]);
    }
    print!(
        "{}",
        render_table("Ablation — serving dtype (8B, TP=4)", &["dtype", "volume"], &rows)
    );
    println!(
        "=> F32 serving doubles every table in the paper; `b` separates structure from width.\n"
    );

    // --- 3. ring vs naive star cost model --------------------------------
    let net: NetModel = Calibration::default().net;
    let msg = (128 * 4096 * 2) as f64; // prefill AllReduce, 8B
    let mut rows = Vec::new();
    for d in [2usize, 4, 8] {
        let ring = net.allreduce(msg, d, false).total();
        // naive star: root receives d-1 full messages then broadcasts:
        // 2(d-1) full-message transfers through one link.
        let star = 2.0 * (d as f64 - 1.0) * (msg / net.nvlink.bus_bw)
            + 2.0 * net.nvlink.alpha_s;
        rows.push(vec![
            format!("d={d}"),
            format!("{:.1} µs", ring * 1e6),
            format!("{:.1} µs", star * 1e6),
            format!("{:.2}x", star / ring),
        ]);
        anyhow::ensure!(star >= ring * 0.9, "ring should not lose to star");
    }
    print!(
        "{}",
        render_table(
            "Ablation — ring vs naive-star AllReduce ([128,4096] BF16, NVLink)",
            &["Group", "Ring", "Star", "Star/Ring"],
            &rows,
        )
    );
    println!(
        "=> the 2(d−1)/d ring factor is what keeps TP's per-GPU bytes flat as d grows \
         (Table III)."
    );
    Ok(())
}
