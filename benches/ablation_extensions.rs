//! Ablations for the paper's named future-work schemes (§VII/§VIII):
//! sequence parallelism and expert parallelism, quantified with the same
//! volume + α–β machinery as the main figures.

use commsim::analysis::{
    ExpertParallelModel, InferenceShape, SequenceParallelModel, VolumeModel,
};
use commsim::cluster::NetModel;
use commsim::model::ModelArch;
use commsim::perfmodel::Calibration;
use commsim::report::{fmt_bytes, render_table};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    let shape = InferenceShape::new(128, 128, 2);
    let net: NetModel = Calibration::default().net;

    // --- Sequence parallelism: same bytes, double the launches ---------
    let mut rows = Vec::new();
    for t in [2usize, 4, 8] {
        let tp = VolumeModel::new(arch.clone()).tensor_parallel(t, shape);
        let sp = SequenceParallelModel::new(arch.clone()).volume(t, shape);
        let spm = SequenceParallelModel::new(arch.clone());
        // Decode-step latency comparison (one token window, intra-node):
        let msg = (arch.hidden * 2) as f64;
        let tp_lat = spm.tp_ops_per_step(t) as f64 * net.allreduce(msg, t, false).total();
        let sp_lat: f64 = spm
            .ops_per_step(t)
            .iter()
            .map(|(k, c)| {
                let cost = match k {
                    commsim::comm::CollectiveKind::ReduceScatter
                    | commsim::comm::CollectiveKind::AllGather => {
                        net.allgather(msg, t, false).total()
                    }
                    _ => net.allreduce(msg, t, false).total(),
                };
                *c as f64 * cost
            })
            .sum();
        rows.push(vec![
            format!("t={t}"),
            fmt_bytes(tp.total()),
            fmt_bytes(sp.total()),
            format!("{:.1} µs", tp_lat * 1e6),
            format!("{:.1} µs", sp_lat * 1e6),
        ]);
        anyhow::ensure!((tp.total() - sp.total()).abs() < 1e-6, "SP volume == TP volume");
        // Ring identity: AllReduce(n) = ReduceScatter(n) + AllGather(n) in
        // both bytes and ring hops — SP is communication-neutral.
        anyhow::ensure!(
            (sp_lat - tp_lat).abs() / tp_lat < 0.01,
            "SP α–β cost equals TP's (ring identity)"
        );
    }
    print!(
        "{}",
        render_table(
            "Ablation — sequence parallelism vs TP (Llama-3.1-8B, Sp=Sd=128)",
            &["TP size", "TP volume", "SP volume", "TP decode comm", "SP decode comm"],
            &rows,
        )
    );
    println!("=> SP is communication-neutral (ring AR ≡ RS+AG); its win is activation memory.");
    println!(
        "   At decode the token window (1) cannot shard across t sequence ranks — why \
         serving engines keep SP off the decode path.\n"
    );

    // --- Expert parallelism: dispatch/combine vs dense AllReduce -------
    let mut rows = Vec::new();
    for (top_k, frac) in [(1usize, 1.0f64), (2, 1.0), (2, 0.5)] {
        let ep = ExpertParallelModel::new(arch.clone(), top_k, frac);
        let (ep_dec, tp_dec) = ep.decode_volume_vs_tp(4, 4, shape);
        rows.push(vec![
            format!("top-{top_k}, {:.0}% MoE layers", frac * 100.0),
            fmt_bytes(ep.volume(4, shape).total()),
            fmt_bytes(ep_dec),
            fmt_bytes(tp_dec),
            if ep_dec < tp_dec { "EP wins".into() } else { "TP wins".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            "Ablation — expert parallelism (e=4) vs dense TP=4 decode volume",
            &["Routing", "EP total volume", "EP decode", "Dense TP decode", "Verdict"],
            &rows,
        )
    );
    println!(
        "=> top-1 routing undercuts dense TP volume; top-2 on every layer exceeds it — \
         capacity factor is the communication knob.\n"
    );

    // --- Prefill/decode disaggregation (DistServe) ----------------------
    use commsim::analysis::DisaggregationModel;
    let m = DisaggregationModel::new(
        arch.clone(),
        commsim::analysis::ParallelLayout::new(4, 1), // prefill pool: TTFT-optimal
        commsim::analysis::ParallelLayout::new(1, 4), // decode pool: volume-optimal
    );
    let mut rows = Vec::new();
    for sd in [16usize, 128, 512] {
        let s = InferenceShape::new(128, sd, 2);
        let v = m.volume(s);
        let colo = m.colocated_volume(commsim::analysis::ParallelLayout::new(4, 1), s);
        rows.push(vec![
            format!("Sd={sd}"),
            fmt_bytes(v.prefill_internal),
            fmt_bytes(v.kv_transfer),
            fmt_bytes(v.decode_internal),
            fmt_bytes(v.total()),
            fmt_bytes(colo),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Ablation — disaggregated prefill(TP4)/decode(PP4) vs colocated TP4 (8B)",
            &[
                "Decode len",
                "Prefill pool",
                "KV migration",
                "Decode pool",
                "Disagg total",
                "Colocated TP4",
            ],
            &rows,
        )
    );
    let be = m
        .break_even_decode_len(commsim::analysis::ParallelLayout::new(4, 1), 128, 2, 4096)
        .unwrap();
    println!(
        "=> KV migration (16 MiB @ Sp=128) amortizes after Sd >= {be}; past that, \
         stage-specialized pools dominate colocated TP on volume."
    );
    Ok(())
}
