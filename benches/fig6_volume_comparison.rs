//! Figure 6 reproduction: total communication volume across parallelism
//! strategies (TP=4, PP=4, TP=2×PP=2) for the three evaluation models,
//! Sp = Sd = 128, BF16.
//!
//! Prints analytical volumes (Eq. 1–7) next to the engine-traced corrected
//! volumes and asserts the paper's ordering: TP highest, PP lowest, hybrid
//! between, monotone in model size.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::{Deployment, DeploymentPlan};
use commsim::report::{bench_json_path, fmt_bytes, render_table, BenchJson, JsonValue};

fn plan_for(arch: &ModelArch, tp: usize, pp: usize) -> anyhow::Result<DeploymentPlan> {
    Ok(Deployment::builder()
        .arch(arch.clone())
        .tp(tp)
        .pp(pp)
        .workload(128, 128)
        .build()?)
}

/// Engine-traced volume under the paper's per-class accounting (one
/// worker-stream for collectives, per-pair for p2p — see DESIGN.md §6).
fn traced_volume(plan: &DeploymentPlan) -> anyhow::Result<f64> {
    let s = plan.trace()?;
    let mut total = 0.0;
    for op in [CollectiveKind::AllReduce, CollectiveKind::AllGather, CollectiveKind::Gather] {
        for stage in [Stage::Prefill, Stage::Decode] {
            total += s.paper_view(op, stage).corrected_volume_bytes;
        }
    }
    // p2p: one rank pair's stream (rank 0 sends; Eq. 7 accounting).
    let layout = plan.layout();
    if layout.pp > 1 {
        total += s.per_rank[0]
            .iter()
            .filter(|(k, _)| k.op == CollectiveKind::Send)
            .map(|(_, v)| v.corrected_volume_bytes)
            .sum::<f64>()
            * (layout.pp - 1) as f64; // rank 0 covers one of the p-1 links
    }
    Ok(total)
}

fn main() -> anyhow::Result<()> {
    let layouts = [(4usize, 1usize), (2, 2), (1, 4)];

    let mut rows = Vec::new();
    let mut analytic: Vec<Vec<f64>> = Vec::new();
    let mut series = Vec::new();
    for arch in ModelArch::paper_models() {
        let mut per_layout = Vec::new();
        for (tp, pp) in layouts {
            let plan = plan_for(&arch, tp, pp)?;
            let a = plan.analyze().total_bytes();
            let t = traced_volume(&plan)?;
            per_layout.push(a);
            series.push((arch.name.clone(), tp, pp, a, t));
            rows.push(vec![
                arch.name.clone(),
                plan.layout().label(),
                fmt_bytes(a),
                fmt_bytes(t),
                format!("{:+.2}%", (t - a) / a * 100.0),
            ]);
        }
        analytic.push(per_layout);
    }
    print!(
        "{}",
        render_table(
            "Fig. 6 — communication volume by strategy (Sp=Sd=128, BF16)",
            &["Model", "Layout", "Analytical (Eq. 1-7)", "Engine-traced", "Δ"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig6_volume_comparison");
        j.param("sp", 128usize).param("sd", 128usize).param("dtype_bytes", 2usize);
        for (model, tp, pp, a, t) in &series {
            j.row(&[
                ("model", JsonValue::from(model.as_str())),
                ("tp", JsonValue::from(*tp)),
                ("pp", JsonValue::from(*pp)),
                ("analytic_bytes", JsonValue::from(*a)),
                ("traced_bytes", JsonValue::from(*t)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    // Paper orderings.
    for (i, arch) in ModelArch::paper_models().iter().enumerate() {
        let (tp, hy, pp) = (analytic[i][0], analytic[i][1], analytic[i][2]);
        anyhow::ensure!(tp > hy && hy > pp, "{}: ordering TP > hybrid > PP", arch.name);
    }
    for (l, &(tp, pp)) in layouts.iter().enumerate() {
        anyhow::ensure!(
            analytic[0][l] < analytic[1][l] && analytic[1][l] < analytic[2][l],
            "volume grows with model size for TP={tp} PP={pp}"
        );
    }
    println!("\nFig. 6 reproduced: TP highest, PP lowest, hybrid between; monotone in model size.");
    Ok(())
}
