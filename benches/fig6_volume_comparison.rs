//! Figure 6 reproduction: total communication volume across parallelism
//! strategies (TP=4, PP=4, TP=2×PP=2) for the three evaluation models,
//! Sp = Sd = 128, BF16.
//!
//! Prints analytical volumes (Eq. 1–7) next to the engine-traced corrected
//! volumes and asserts the paper's ordering: TP highest, PP lowest, hybrid
//! between, monotone in model size.

use commsim::analysis::{InferenceShape, ParallelLayout, VolumeModel};
use commsim::comm::CollectiveKind;
use commsim::engine::{Engine, EngineConfig};
use commsim::model::ModelArch;
use commsim::report::{fmt_bytes, render_table};

/// Engine-traced volume under the paper's per-class accounting (one
/// worker-stream for collectives, per-pair for p2p — see DESIGN.md §6).
fn traced_volume(arch: &ModelArch, layout: ParallelLayout) -> anyhow::Result<f64> {
    let mut engine = Engine::new(EngineConfig::structural(arch.clone(), layout))?;
    engine.generate(&vec![0i32; 128], 128)?;
    let s = engine.trace().summary();
    let mut total = 0.0;
    for op in [CollectiveKind::AllReduce, CollectiveKind::AllGather, CollectiveKind::Gather] {
        for stage in [commsim::comm::Stage::Prefill, commsim::comm::Stage::Decode] {
            total += s.paper_view(op, stage).corrected_volume_bytes;
        }
    }
    // p2p: one rank pair's stream (rank 0 sends; Eq. 7 accounting).
    if layout.pp > 1 {
        total += s.per_rank[0]
            .iter()
            .filter(|(k, _)| k.op == CollectiveKind::Send)
            .map(|(_, v)| v.corrected_volume_bytes)
            .sum::<f64>()
            * (layout.pp - 1) as f64; // rank 0 covers one of the p-1 links
    }
    Ok(total)
}

fn main() -> anyhow::Result<()> {
    let shape = InferenceShape::new(128, 128, 2);
    let layouts = [
        ParallelLayout::new(4, 1),
        ParallelLayout::new(2, 2),
        ParallelLayout::new(1, 4),
    ];

    let mut rows = Vec::new();
    let mut analytic: Vec<Vec<f64>> = Vec::new();
    for arch in ModelArch::paper_models() {
        let vm = VolumeModel::new(arch.clone());
        let mut per_layout = Vec::new();
        for layout in layouts {
            let a = vm.volume(layout, shape).total();
            let t = traced_volume(&arch, layout)?;
            per_layout.push(a);
            rows.push(vec![
                arch.name.clone(),
                layout.label(),
                fmt_bytes(a),
                fmt_bytes(t),
                format!("{:+.2}%", (t - a) / a * 100.0),
            ]);
        }
        analytic.push(per_layout);
    }
    print!(
        "{}",
        render_table(
            "Fig. 6 — communication volume by strategy (Sp=Sd=128, BF16)",
            &["Model", "Layout", "Analytical (Eq. 1-7)", "Engine-traced", "Δ"],
            &rows,
        )
    );

    // Paper orderings.
    for (i, arch) in ModelArch::paper_models().iter().enumerate() {
        let (tp, hy, pp) = (analytic[i][0], analytic[i][1], analytic[i][2]);
        anyhow::ensure!(tp > hy && hy > pp, "{}: ordering TP > hybrid > PP", arch.name);
    }
    for l in 0..layouts.len() {
        anyhow::ensure!(
            analytic[0][l] < analytic[1][l] && analytic[1][l] < analytic[2][l],
            "volume grows with model size for {}",
            layouts[l].label()
        );
    }
    println!("\nFig. 6 reproduced: TP highest, PP lowest, hybrid between; monotone in model size.");
    Ok(())
}
