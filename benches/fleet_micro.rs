//! Microbenchmark: fleet DES throughput at scale.
//!
//! A 100k-request Poisson trace over an 8-replica colocated tiny fleet
//! exercises the simulator's hot path — the replica-clock index, the
//! allocation-free routing snapshots, and summary-only tracing — and
//! reports the wall-clock event rate. Every number in the JSON artifact
//! is a deterministic modeled quantity (bench-diff gates those); the
//! wall clock is stamped as the advisory `wall_s` only.

use commsim::fleet::{self, FleetSpec, RouterPolicy, SloTarget};
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, BenchJson, JsonValue};
use commsim::server::SchedulerConfig;
use commsim::workload::{ArrivalProcess, LengthDist, WorkloadSpec};

const REQUESTS: usize = 100_000;
const REPLICAS: usize = 8;
const SEED: u64 = 0xF1EE7;

fn main() -> anyhow::Result<()> {
    let plan = Deployment::builder().model("tiny").tp(1).pp(1).workload(8, 2).build()?;
    // An effectively-unbounded queue: the bench measures DES throughput,
    // and offered load beyond the fleet's service rate must pile up in
    // queues (stretching makespan deterministically), not overflow into
    // rejections.
    let sched = SchedulerConfig { max_queue: REQUESTS, ..SchedulerConfig::default() };
    let spec = FleetSpec::colocated(&plan, REPLICAS)?
        .with_scheduler(sched)
        .with_router(RouterPolicy::LeastOutstandingTokens);
    let workload = WorkloadSpec {
        arrivals: ArrivalProcess::poisson(20_000.0),
        prompt: LengthDist::Fixed(8),
        decode: LengthDist::Fixed(2),
        prefix: None,
        requests: REQUESTS,
    };

    println!(
        "fleet DES microbenchmark: {REQUESTS} requests over {REPLICAS} colocated tiny \
         replicas, seed={SEED:#x}\n"
    );
    let start = std::time::Instant::now();
    let s = spec.simulate(&workload, SEED)?;
    let wall = start.elapsed().as_secs_f64();
    anyhow::ensure!(
        s.completed == REQUESTS && s.failed == 0,
        "the fleet must serve the whole trace ({} completed, {} failed)",
        s.completed,
        s.failed
    );
    println!(
        "simulate: {wall:.3} s wall, {} DES events -> {:.0} events/s",
        s.events,
        s.events as f64 / wall.max(1e-9)
    );
    println!(
        "model: makespan {:.3} s, TTFT p95 {:.3} ms, E2E p95 {:.3} s, comm {:.3e} B",
        s.model.makespan_s,
        s.model.ttft.p95_s * 1e3,
        s.model.e2e.p95_s,
        s.comm_bytes
    );

    // Capacity sweep, threaded vs sequential, over a smaller paired
    // trace: same candidates, same seed — the outputs are asserted
    // identical, only the wall clock differs.
    let sweep_wl = WorkloadSpec { requests: 10_000, ..workload };
    let sweep_specs = || -> anyhow::Result<Vec<FleetSpec>> {
        (1..=4)
            .map(|n| {
                Ok(FleetSpec::colocated(&plan, n)?
                    .with_router(RouterPolicy::LeastOutstandingTokens))
            })
            .collect()
    };
    let target = SloTarget::default();
    let t0 = std::time::Instant::now();
    let seq = fleet::capacity_sweep_sequential(sweep_specs()?, &sweep_wl, SEED, target)?;
    let seq_wall = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let thr = fleet::capacity_sweep(sweep_specs()?, &sweep_wl, SEED, target)?;
    let thr_wall = t1.elapsed().as_secs_f64();
    for (a, b) in seq.iter().zip(&thr) {
        anyhow::ensure!(
            format!("{a:?}") == format!("{b:?}"),
            "threaded sweep must match the sequential path bitwise"
        );
    }
    println!(
        "\ncapacity sweep (4 candidates x 10k requests): sequential {seq_wall:.3} s, \
         threaded {thr_wall:.3} s ({:.2}x) — outputs bitwise-identical",
        seq_wall / thr_wall.max(1e-9)
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fleet_micro");
        j.param("model", "tiny")
            .param("requests", REQUESTS)
            .param("replicas", REPLICAS)
            .param("router", "least-tokens");
        // Modeled numbers only: bench-diff gates these rows, so nothing
        // wall-clock-derived may appear here (wall_s is stamped at the
        // artifact's top level as advisory metadata).
        j.row(&[
            ("makespan_s", JsonValue::from(s.model.makespan_s)),
            ("ttft_p95_s", JsonValue::from(s.model.ttft.p95_s)),
            ("tpot_p95_s", JsonValue::from(s.model.tpot.p95_s)),
            ("e2e_p95_s", JsonValue::from(s.model.e2e.p95_s)),
            ("comm_bytes", JsonValue::from(s.comm_bytes)),
            ("events", JsonValue::from(s.events as usize)),
        ]);
        j.write(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}
