//! Table VI reproduction: message size & frequency for hybrid TP=2 × PP=2,
//! Llama-3.1-8B, Sp = Sd = 128.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_shape, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    // Paper Table VI (paper-view convention: the rank observing the most of
    // each op class — §IV.B excludes rank 0 and reads one worker profile).
    let paper: &[(Stage, CollectiveKind, usize, Vec<usize>)] = &[
        (Stage::Prefill, CollectiveKind::AllReduce, 33, vec![128, 4096]),
        (Stage::Prefill, CollectiveKind::Gather, 1, vec![64128]),
        (Stage::Prefill, CollectiveKind::AllGather, 2, vec![128, 4096]),
        (Stage::Prefill, CollectiveKind::Send, 2, vec![128, 2048]),
        (Stage::Decode, CollectiveKind::AllReduce, 4191, vec![1, 4096]),
        (Stage::Decode, CollectiveKind::Gather, 127, vec![64128]),
        (Stage::Decode, CollectiveKind::AllGather, 254, vec![1, 4096]),
        (Stage::Decode, CollectiveKind::Send, 254, vec![1, 2048]),
    ];

    let plan = Deployment::builder()
        .arch(arch.clone())
        .tp(2)
        .pp(2)
        .workload(128, 128)
        .build()?;
    // Time only the generate (comparable to pre-facade baselines), not
    // the worker-group spawn inside engine().
    let mut engine = plan.engine()?;
    let t0 = std::time::Instant::now();
    engine.generate(&[0i32; 128], 128)?;
    let elapsed = t0.elapsed();
    let summary = engine.trace().summary();
    let predicted = plan.analyze();

    let mut rows = Vec::new();
    let mut failures = 0;
    for (stage, op, pcount, pshape) in paper {
        let measured = summary.paper_view(*op, *stage);
        let acount = predicted.ops(*stage).count(*op);
        let mshape = summary.shapes(*op, *stage).first().cloned().unwrap_or_default();
        let ok = measured.count == *pcount && acount == *pcount && mshape == *pshape;
        if !ok {
            failures += 1;
        }
        rows.push(vec![
            format!("{} ({})", op.label(), stage.label()),
            pcount.to_string(),
            fmt_shape(pshape),
            acount.to_string(),
            measured.count.to_string(),
            fmt_shape(&mshape),
            if ok { "OK".into() } else { "MISMATCH".into() },
        ]);
    }
    print!(
        "{}",
        render_table(
            &format!("Table VI — {} TP=2 PP=2 (engine run {elapsed:.2?})", arch.name),
            &[
                "Operation",
                "Paper count",
                "Paper shape",
                "Analytical",
                "Measured",
                "Measured shape",
                "",
            ],
            &rows,
        )
    );
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("table6_hybrid_profile");
        j.param("model", arch.name.as_str())
            .param("tp", 2usize)
            .param("pp", 2usize)
            .param("sp", 128usize)
            .param("sd", 128usize)
            .param("engine_run_s", elapsed.as_secs_f64());
        for (stage, op, _pcount, _pshape) in paper {
            let measured = summary.paper_view(*op, *stage);
            j.row(&[
                ("op", JsonValue::from(op.label())),
                ("stage", JsonValue::from(stage.label())),
                ("count", JsonValue::from(measured.count)),
                ("message_bytes", JsonValue::from(measured.total_message_bytes)),
                ("modeled_s", JsonValue::from(measured.modeled_time_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} rows mismatched the paper");
    }
    println!("\nTable VI fully reproduced (counts and shapes exact).");
    Ok(())
}
