//! Figure 8 reproduction: Llama-3.2-3B SLO metrics across tensor
//! parallelism degrees (TP=2, 4 intra-node; TP=8 across two nodes),
//! Sp = Sd = 128.
//!
//! Latency is simulated (no H100s here — DESIGN.md §5): H100 roofline +
//! α–β collectives + calibrated vLLM-V0 framework overheads. The paper's
//! published numbers are printed alongside; the acceptance criteria are the
//! orderings and ≤25-35% deviation.

use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama32_3b();
    // Paper Fig. 8: (tp, e2e s, ttft ms, tpot ms).
    let paper = [
        (2usize, 0.310f64, 150.0f64, 1.17f64),
        (4, 0.210, 90.0, 0.86),
        (8, 1.520, 30.0, 11.56),
    ];

    let mut rows = Vec::new();
    let mut sims = Vec::new();
    let mut sims8 = Vec::new();
    for (tp, p_e2e, p_ttft, p_tpot) in paper {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(tp)
            .workload(128, 128)
            .build()?;
        let r = plan.simulate();
        sims.push((tp, r));
        rows.push(vec![
            format!("TP={tp}{}", if tp == 8 { " (2 nodes)" } else { "" }),
            format!("{:.3} / {:.3}", p_e2e, r.e2e_s),
            format!("{:.0} / {:.1}", p_ttft, r.ttft_s * 1e3),
            format!("{:.2} / {:.2}", p_tpot, r.tpot_s * 1e3),
        ]);
        // Same layout on an int8 wire — the paper has no published row for
        // this, so the table shows simulated numbers only and the
        // deviation gates below stay on the bf16 rows.
        let tuned = Deployment::builder()
            .arch(arch.clone())
            .tp(tp)
            .workload(128, 128)
            .collective_tuning(8, 0.0)
            .build()?
            .simulate();
        sims8.push((tp, tuned));
        rows.push(vec![
            format!("TP={tp} @int8 wire"),
            format!("   -  / {:.3}", tuned.e2e_s),
            format!("  -  / {:.1}", tuned.ttft_s * 1e3),
            format!("  -  / {:.2}", tuned.tpot_s * 1e3),
        ]);
    }
    print!(
        "{}",
        render_table(
            "Fig. 8 — Llama-3.2-3B SLOs vs TP degree (paper / simulated)",
            &["Config", "E2E (s)", "TTFT (ms)", "TPOT (ms)"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig8_tp_slo");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (bits, set) in [(16usize, &sims), (8, &sims8)] {
            for (tp, r) in set {
                j.row(&[
                    ("tp", JsonValue::from(*tp)),
                    ("wire_bits", JsonValue::from(bits)),
                    ("ttft_s", JsonValue::from(r.ttft_s)),
                    ("tpot_s", JsonValue::from(r.tpot_s)),
                    ("e2e_s", JsonValue::from(r.e2e_s)),
                ]);
            }
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    let r = |tp: usize| sims.iter().find(|(t, _)| *t == tp).unwrap().1;
    // Paper's qualitative findings.
    anyhow::ensure!(r(4).ttft_s < r(2).ttft_s && r(8).ttft_s < r(4).ttft_s,
        "TTFT keeps improving with TP (prefill is compute-bound)");
    anyhow::ensure!(r(4).tpot_s < r(2).tpot_s, "TP=4 improves TPOT intra-node");
    anyhow::ensure!(r(8).tpot_s > 5.0 * r(4).tpot_s,
        "cross-node TP=8 degrades TPOT (decode becomes communication-bound)");
    anyhow::ensure!(r(8).e2e_s > r(2).e2e_s, "E2E degrades at TP=8");
    // The int8 wire never makes any SLO worse, and it bites hardest where
    // decode is most communication-bound (cross-node TP=8).
    let r8 = |tp: usize| sims8.iter().find(|(t, _)| *t == tp).unwrap().1;
    for (tp, ..) in paper {
        anyhow::ensure!(r8(tp).e2e_s <= r(tp).e2e_s, "int8 E2E regressed at TP={tp}");
        anyhow::ensure!(r8(tp).tpot_s <= r(tp).tpot_s, "int8 TPOT regressed at TP={tp}");
    }
    anyhow::ensure!(
        (r(8).tpot_s - r8(8).tpot_s) >= (r(4).tpot_s - r8(4).tpot_s),
        "compressing the wire must save the most TPOT where comm dominates"
    );
    for (tp, p_e2e, _p_ttft, p_tpot) in paper {
        let s = r(tp);
        anyhow::ensure!((s.e2e_s - p_e2e).abs() / p_e2e < 0.35, "TP={tp} E2E within 35%");
        anyhow::ensure!(
            (s.tpot_s * 1e3 - p_tpot).abs() / p_tpot < 0.35,
            "TP={tp} TPOT within 35%"
        );
    }
    println!("\nFig. 8 reproduced: TTFT monotone, TPOT valley at TP=4, cross-node blow-up.");
    Ok(())
}
