//! Table III reproduction: message size & frequency breakdown for
//! intra-node TP, Llama-3.1-8B, Sp = Sd = 128, TP ∈ {2, 4}.
//!
//! Runs the structural engine through the deployment-plan facade
//! (identical communication stream to the real one; compute stubbed —
//! DESIGN.md §5) and prints measured counts/shapes next to the analytical
//! model and the paper's published values.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_shape, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    // Paper Table III rows: (tp, stage, op, count, shape).
    let paper: &[(usize, Stage, CollectiveKind, usize, Vec<usize>)] = &[
        (2, Stage::Prefill, CollectiveKind::AllReduce, 65, vec![128, 4096]),
        (2, Stage::Prefill, CollectiveKind::Gather, 1, vec![64128]),
        (2, Stage::Decode, CollectiveKind::AllReduce, 8255, vec![1, 4096]),
        (2, Stage::Decode, CollectiveKind::Gather, 127, vec![64128]),
        (4, Stage::Prefill, CollectiveKind::AllReduce, 65, vec![128, 4096]),
        (4, Stage::Prefill, CollectiveKind::Gather, 1, vec![32064]),
        (4, Stage::Decode, CollectiveKind::AllReduce, 8255, vec![1, 4096]),
        (4, Stage::Decode, CollectiveKind::Gather, 127, vec![32064]),
    ];

    let mut failures = 0;
    let mut series = Vec::new();
    for tp in [2usize, 4] {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .tp(tp)
            .workload(128, 128)
            .build()?;
        // Time only the generate (comparable to pre-facade baselines),
        // not the worker-group spawn inside engine().
        let mut engine = plan.engine()?;
        let t0 = std::time::Instant::now();
        engine.generate(&[0i32; 128], 128)?;
        let elapsed = t0.elapsed();
        let summary = engine.trace().summary();
        let predicted = plan.analyze();

        let mut rows = Vec::new();
        for (_ptp, stage, op, pcount, pshape) in paper.iter().filter(|r| r.0 == tp) {
            let measured = summary.paper_view(*op, *stage);
            let mshape = summary
                .shapes(*op, *stage)
                .first()
                .cloned()
                .unwrap_or_default();
            let acount = predicted.ops(*stage).count(*op);
            let ok = measured.count == *pcount && acount == *pcount && mshape == *pshape;
            if !ok {
                failures += 1;
            }
            series.push((
                tp,
                op.label(),
                stage.label(),
                measured.count,
                measured.total_message_bytes,
                elapsed.as_secs_f64(),
            ));
            rows.push(vec![
                format!("{} ({})", op.label(), stage.label()),
                pcount.to_string(),
                fmt_shape(pshape),
                acount.to_string(),
                measured.count.to_string(),
                fmt_shape(&mshape),
                if ok { "OK".into() } else { "MISMATCH".into() },
            ]);
        }
        print!(
            "{}",
            render_table(
                &format!("Table III — {} TP={tp} (engine run {elapsed:.2?})", arch.name),
                &[
                    "Collective",
                    "Paper count",
                    "Paper shape",
                    "Analytical",
                    "Measured",
                    "Measured shape",
                    "",
                ],
                &rows,
            )
        );
        println!();
    }
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("table3_tp_profile");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (tp, op, stage, count, bytes, run_s) in &series {
            j.row(&[
                ("tp", JsonValue::from(*tp)),
                ("op", JsonValue::from(*op)),
                ("stage", JsonValue::from(*stage)),
                ("count", JsonValue::from(*count)),
                ("message_bytes", JsonValue::from(*bytes)),
                ("engine_run_s", JsonValue::from(*run_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} rows mismatched the paper");
    }
    println!("Table III fully reproduced (counts and shapes exact).");
    Ok(())
}
