//! Chunked-prefill pricing ledger: what a Sarathi-style split costs the
//! prompt owner and what it saves the decode victims.
//!
//! For a long prompt on a colocated replica, one-shot prefill stalls every
//! in-flight decode for the full prefill duration. Splitting the prompt
//! into token-budgeted chunks and fusing each chunk with the running
//! decode batch (one mixed iteration) re-prices the interference: the
//! owner's TTFT stretches by the extra per-chunk launches and gathers,
//! while each victim's stall shrinks to the chunk compute plus the comm
//! *growth* of the fused window — the per-launch α terms are paid by the
//! decode iteration that runs anyway. This bench prints both sides of
//! that ledger per layout and budget, and pins the qualitative claims.

use commsim::analysis::{InferenceShape, ParallelLayout};
use commsim::model::ModelArch;
use commsim::report::{bench_json_path, render_table, BenchJson, JsonValue};
use commsim::simtime::CostModel;

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    let prompt = 2048usize;
    let victims = 4usize; // in-flight decodes sharing the replica
    let layouts = [(2usize, 1usize), (4, 1), (2, 2)];
    let budgets = [256usize, 512, 1024];

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for (tp, pp) in layouts {
        let cm = CostModel::on_cardinal(arch.clone(), ParallelLayout::new(tp, pp));
        let one_shot = cm.prefill_breakdown(InferenceShape::new(prompt, 1, 2));
        let label = ParallelLayout::new(tp, pp).label();
        rows.push(vec![
            label.clone(),
            "one-shot".into(),
            "1".into(),
            format!("{:.1} ms", one_shot.total() * 1e3),
            "—".into(),
            format!("{:.1} ms", one_shot.total() * 1e3),
            "100.0%".into(),
        ]);
        series.push((tp, pp, 0usize, 1usize, one_shot.total(), one_shot.total()));

        for budget in budgets {
            // Price the split: each chunk rides one mixed iteration with
            // the decode batch, whose contexts advance a token per step.
            let mut kv_lens = vec![prompt + 64; victims];
            let mut owner = 0.0; // Σ chunk iteration price → the owner's TTFT stretch
            let mut compute = 0.0;
            let mut comm = 0.0;
            let mut stall = 0.0; // Σ (mixed − decode-only) → per-victim TPOT stretch
            let mut chunks = 0usize;
            let mut start = 0usize;
            while start < prompt {
                let len = budget.min(prompt - start);
                let chunk = cm.prefill_chunk_breakdown(start, len);
                owner += chunk.total();
                compute += chunk.compute_s;
                comm += chunk.comm_s;
                stall += cm.mixed_iteration(start, len, &kv_lens).total()
                    - cm.decode_iteration(&kv_lens).total();
                for kv in kv_lens.iter_mut() {
                    *kv += 1;
                }
                start += len;
                chunks += 1;
            }

            // The chunk split never underprices the one-shot prefill: the
            // attention quadratic telescopes exactly, and every extra chunk
            // pays its own collective launches and logits gather.
            anyhow::ensure!(
                (compute - one_shot.compute_s).abs() <= 1e-9 * one_shot.compute_s,
                "chunk compute must telescope to the one-shot prefill at {label} budget {budget}"
            );
            anyhow::ensure!(
                comm > one_shot.comm_s && owner > one_shot.total(),
                "a {chunks}-chunk split must cost the owner more than one-shot at {label}"
            );
            // The victims' ledger runs the other way: fused launches cancel
            // the α terms against the decode iteration that runs anyway, so
            // the summed stall lands strictly below the one-shot stall.
            anyhow::ensure!(
                stall < one_shot.total(),
                "chunked victim stall must undercut the one-shot stall at {label} budget {budget}"
            );

            rows.push(vec![
                label.clone(),
                format!("{budget}"),
                format!("{chunks}"),
                format!("{:.1} ms", owner * 1e3),
                format!("+{:.2} ms", (owner - one_shot.total()) * 1e3),
                format!("{:.1} ms", stall * 1e3),
                format!("{:.1}%", stall / one_shot.total() * 100.0),
            ]);
            series.push((tp, pp, budget, chunks, owner, stall));
        }
    }
    print!(
        "{}",
        render_table(
            "Chunked prefill — owner cost vs decode-victim stall, Llama-3.1-8B, Sp=2048, 4 victims",
            &["Layout", "Budget", "Chunks", "Owner prefill", "vs one-shot", "Victim stall", "of one-shot"],
            &rows,
        )
    );

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("chunked_prefill_interference");
        j.param("model", arch.name.as_str())
            .param("sp", prompt)
            .param("victims", victims);
        for (tp, pp, budget, chunks, owner, stall) in &series {
            j.row(&[
                ("tp", JsonValue::from(*tp)),
                ("pp", JsonValue::from(*pp)),
                ("chunk_tokens", JsonValue::from(*budget)),
                ("chunks", JsonValue::from(*chunks)),
                ("owner_prefill_s", JsonValue::from(*owner)),
                ("victim_stall_s", JsonValue::from(*stall)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }

    println!("\nLedger holds: every split costs the owner, every split spares the victims.");
    Ok(())
}
