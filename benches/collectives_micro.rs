//! Microbenchmark: the in-process collective library (AllReduce /
//! AllGather / Gather / p2p) across group sizes and message sizes — the L3
//! hot path underneath every decode step. Used by the §Perf pass.

use std::thread;

use commsim::comm::collectives::CommWorld;
use commsim::comm::{Stage, TraceSink};
use commsim::testutil::bench;

fn bench_allreduce(size: usize, elems: usize, rounds: usize) {
    let sink = TraceSink::new();
    sink.set_enabled(false); // measure the data path, not the tracer
    let world = CommWorld::new(size, 4, sink);
    let handles = world.create_group(&(0..size).collect::<Vec<_>>());
    thread::scope(|s| {
        for h in handles {
            s.spawn(move || {
                let mut buf = vec![1.0f32; elems];
                for _ in 0..rounds {
                    h.all_reduce(&mut buf, &[elems], Stage::Decode);
                }
            });
        }
    });
}

fn main() {
    println!("collective microbenchmarks (per-op latency = mean/rounds)\n");
    for (size, elems, rounds) in [
        (2usize, 4096usize, 200usize), // decode-step AllReduce [1, 4096]
        (4, 4096, 200),
        (8, 4096, 100),
        (2, 128 * 4096, 20), // prefill AllReduce [128, 4096]
        (4, 128 * 4096, 20),
    ] {
        let stats = bench(
            &format!("allreduce d={size} elems={elems}"),
            1,
            10,
            || bench_allreduce(size, elems, rounds),
        );
        let per_op = stats.mean / rounds as u32;
        println!("{}  -> {:?}/op", stats.report(), per_op);
    }

    // Tracing overhead: same op with the sink enabled.
    for enabled in [false, true] {
        let sink = TraceSink::new();
        sink.set_enabled(enabled);
        let world = CommWorld::new(2, 4, sink);
        let handles = world.create_group(&[0, 1]);
        let stats = bench(
            &format!("allreduce d=2 elems=4096 trace={enabled}"),
            1,
            10,
            || {
                let hs = handles.clone();
                thread::scope(|s| {
                    for h in hs {
                        s.spawn(move || {
                            let mut buf = vec![1.0f32; 4096];
                            for _ in 0..200 {
                                h.all_reduce(&mut buf, &[1, 4096], Stage::Decode);
                            }
                        });
                    }
                });
            },
        );
        println!("{}", stats.report());
    }

    // p2p throughput (fresh endpoints per iteration; Sender moves into the
    // producer thread, Receiver drains on this one).
    let sink = TraceSink::new();
    sink.set_enabled(false);
    let world = CommWorld::new(2, 4, sink);
    let rx = world.receiver(0, 1);
    let stats = bench("p2p send+recv elems=4096 x200", 1, 10, || {
        let tx = world.sender(0, 1);
        thread::scope(|s| {
            s.spawn(move || {
                for _ in 0..200 {
                    tx.send(vec![1.0f32; 4096], &[1, 4096], Stage::Decode);
                }
            });
            for _ in 0..200 {
                let _ = rx.recv(&[1, 4096], Stage::Decode);
            }
        });
    });
    println!("{}", stats.report());
}
