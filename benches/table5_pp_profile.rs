//! Table V reproduction: Send/Recv message size & frequency for pipeline
//! parallelism, Llama-3.1-8B, Sp = Sd = 128, PP ∈ {2, 4}.

use commsim::comm::{CollectiveKind, Stage};
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_shape, render_table, BenchJson, JsonValue};

fn main() -> anyhow::Result<()> {
    let arch = ModelArch::llama31_8b();
    // Paper Table V: (pp, stage, op, count, shape) — counts are global
    // (summed across ranks), matching the paper's aggregate view.
    let paper: &[(usize, Stage, CollectiveKind, usize, Vec<usize>)] = &[
        (2, Stage::Prefill, CollectiveKind::Send, 2, vec![128, 4096]),
        (2, Stage::Prefill, CollectiveKind::Recv, 2, vec![128, 4096]),
        (2, Stage::Decode, CollectiveKind::Send, 254, vec![1, 4096]),
        (2, Stage::Decode, CollectiveKind::Recv, 254, vec![1, 4096]),
        (4, Stage::Prefill, CollectiveKind::Send, 6, vec![128, 4096]),
        (4, Stage::Prefill, CollectiveKind::Recv, 6, vec![128, 4096]),
        (4, Stage::Decode, CollectiveKind::Send, 762, vec![1, 4096]),
        (4, Stage::Decode, CollectiveKind::Recv, 762, vec![1, 4096]),
    ];

    let mut failures = 0;
    let mut series = Vec::new();
    for pp in [2usize, 4] {
        let plan = Deployment::builder()
            .arch(arch.clone())
            .pp(pp)
            .workload(128, 128)
            .build()?;
        // Time only the generate (comparable to pre-facade baselines),
        // not the worker-group spawn inside engine().
        let mut engine = plan.engine()?;
        let t0 = std::time::Instant::now();
        engine.generate(&[0i32; 128], 128)?;
        let elapsed = t0.elapsed();
        let summary = engine.trace().summary();
        let predicted = plan.analyze();

        let mut rows = Vec::new();
        for (_ppp, stage, op, pcount, pshape) in paper.iter().filter(|r| r.0 == pp) {
            // Table V is the paper's *global* view (each transfer once).
            let mcount = summary.global_count(*op, *stage);
            let acount = predicted.global_ops(*stage).count(*op);
            let mshape = summary
                .shapes(*op, *stage)
                .first()
                .cloned()
                .unwrap_or_default();
            let ok = mcount == *pcount && acount == *pcount && mshape == *pshape;
            if !ok {
                failures += 1;
            }
            series.push((pp, op.label(), stage.label(), mcount, elapsed.as_secs_f64()));
            rows.push(vec![
                format!("{} ({})", op.label(), stage.label()),
                pcount.to_string(),
                fmt_shape(pshape),
                acount.to_string(),
                mcount.to_string(),
                fmt_shape(&mshape),
                if ok { "OK".into() } else { "MISMATCH".into() },
            ]);
        }
        print!(
            "{}",
            render_table(
                &format!("Table V — {} PP={pp} (engine run {elapsed:.2?})", arch.name),
                &[
                    "Operation",
                    "Paper count",
                    "Paper shape",
                    "Analytical",
                    "Measured",
                    "Measured shape",
                    "",
                ],
                &rows,
            )
        );
        println!();
    }
    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("table5_pp_profile");
        j.param("model", arch.name.as_str()).param("sp", 128usize).param("sd", 128usize);
        for (pp, op, stage, count, run_s) in &series {
            j.row(&[
                ("pp", JsonValue::from(*pp)),
                ("op", JsonValue::from(*op)),
                ("stage", JsonValue::from(*stage)),
                ("count", JsonValue::from(*count)),
                ("engine_run_s", JsonValue::from(*run_s)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    if failures > 0 {
        anyhow::bail!("{failures} rows mismatched the paper");
    }
    println!("Table V fully reproduced (counts and shapes exact).");
    Ok(())
}
