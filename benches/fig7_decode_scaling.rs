//! Figure 7 reproduction: communication volume scaling with decode
//! sequence length (Sd ∈ {128, 256, 512}, Sp = 128) across parallelism
//! strategies and models.
//!
//! Asserts the paper's sub-linear growth factors: ≈1.50× for 128→256 and
//! ≈1.67× for 256→512 (the `(S_p + S_d − 1)` dilution), PP lowest volume,
//! TP growing fastest in absolute terms.
//!
//! Batch-dimension variant (beyond the paper's single-request methodology,
//! §IV.B): continuous batching puts B sequences into every decode
//! iteration, so the per-iteration AllReduce payload is `[B, h]` — the
//! measured, batch-tagged trace must scale *linearly* with the active
//! batch size (the axis arXiv:2408.10197 / arXiv:2407.14645 model).

use commsim::analysis::ParallelLayout;
use commsim::comm::{CollectiveKind, Stage};
use commsim::engine::SequenceInput;
use commsim::model::ModelArch;
use commsim::plan::Deployment;
use commsim::report::{bench_json_path, fmt_bytes, render_table, BenchJson, JsonValue};

fn volume(arch: &ModelArch, tp: usize, pp: usize, sd: usize) -> anyhow::Result<f64> {
    let plan = Deployment::builder()
        .arch(arch.clone())
        .tp(tp)
        .pp(pp)
        .workload(128, sd)
        .build()?;
    Ok(plan.analyze().total_bytes())
}

fn main() -> anyhow::Result<()> {
    let layouts = [(4usize, 1usize), (2, 2), (1, 4)];
    let sds = [128usize, 256, 512];

    let mut rows = Vec::new();
    let mut series = Vec::new();
    for arch in ModelArch::paper_models() {
        for (tp, pp) in layouts {
            let vols: Vec<f64> = sds
                .iter()
                .map(|&sd| volume(&arch, tp, pp, sd))
                .collect::<anyhow::Result<_>>()?;
            let g1 = vols[1] / vols[0];
            let g2 = vols[2] / vols[1];
            series.push((arch.name.clone(), tp, pp, vols.clone()));
            let label = ParallelLayout::new(tp, pp).label();
            rows.push(vec![
                arch.name.clone(),
                label.clone(),
                fmt_bytes(vols[0]),
                fmt_bytes(vols[1]),
                fmt_bytes(vols[2]),
                format!("{g1:.3}x / {g2:.3}x"),
            ]);
            // Paper: ~1.50x and ~1.67x growth from the (Sp+Sd−1) dilution.
            // PP and TP=4 track the quoted factors tightly; the hybrid
            // layout carries a larger Gather share (∝ Sd, v/t = 64128 at
            // t=2) so its growth sits slightly higher but stays sub-linear.
            if pp == 1 || tp == 1 {
                anyhow::ensure!((g1 - 1.50).abs() < 0.04, "{} {label}: g1={g1}", arch.name);
                anyhow::ensure!((g2 - 1.67).abs() < 0.04, "{} {label}: g2={g2}", arch.name);
            } else {
                anyhow::ensure!((1.45..1.75).contains(&g1), "{} {label}: g1={g1}", arch.name);
                anyhow::ensure!((1.55..1.90).contains(&g2), "{} {label}: g2={g2}", arch.name);
            }
            anyhow::ensure!(g1 < 2.0 && g2 < 2.0, "sub-linear in the 2x length step");
        }
    }
    print!(
        "{}",
        render_table(
            "Fig. 7 — volume vs decode length (Sp=128, BF16)",
            &["Model", "Layout", "Sd=128", "Sd=256", "Sd=512", "Growth 128→256 / 256→512"],
            &rows,
        )
    );

    // PP stays lowest at every Sd; TP grows fastest absolutely.
    for arch in ModelArch::paper_models() {
        for &sd in &sds {
            let tp = volume(&arch, 4, 1, sd)?;
            let hy = volume(&arch, 2, 2, sd)?;
            let pp = volume(&arch, 1, 4, sd)?;
            anyhow::ensure!(pp < hy && hy < tp, "{} Sd={sd} ordering", arch.name);
        }
    }
    println!("\nFig. 7 reproduced: sub-linear growth 1.50x/1.67x, PP lowest at every length.");

    // --- batch dimension: decode AllReduce payload vs active batch size --
    let batches = [1usize, 2, 4, 8];
    let mut rows = Vec::new();
    let mut per_record = Vec::new();
    for &b in &batches {
        let (count, bytes) = decode_allreduce_at_batch(b)?;
        anyhow::ensure!(count > 0, "no batch-tagged decode AllReduce at B={b}");
        anyhow::ensure!(bytes % count == 0, "uniform records at B={b}");
        per_record.push(bytes / count);
        rows.push(vec![
            format!("B={b}"),
            count.to_string(),
            fmt_bytes((bytes / count) as f64),
            fmt_bytes(bytes as f64),
        ]);
    }
    print!(
        "\n{}",
        render_table(
            "Fig. 7 batch variant — decode AllReduce vs active batch (8B, TP=2, structural)",
            &["Batch", "Count", "Per-record", "Total"],
            &rows,
        )
    );
    for (i, &b) in batches.iter().enumerate() {
        anyhow::ensure!(
            per_record[i] == b * per_record[0],
            "decode AllReduce payload must scale linearly with batch: B={b} \
             per-record {} vs {}x{}",
            per_record[i],
            b,
            per_record[0]
        );
    }
    println!("\nBatch variant verified: per-iteration decode AllReduce payload is linear in B.");

    if let Some(path) = bench_json_path()? {
        let mut j = BenchJson::new("fig7_decode_scaling");
        j.param("sp", 128usize).param("dtype_bytes", 2usize);
        // Two row kinds share the file; `series` keys them so a generic
        // per-key differ can group before comparing.
        for (model, tp, pp, vols) in &series {
            for (&sd, &v) in sds.iter().zip(vols.iter()) {
                j.row(&[
                    ("series", JsonValue::from("volume_vs_sd")),
                    ("model", JsonValue::from(model.as_str())),
                    ("tp", JsonValue::from(*tp)),
                    ("pp", JsonValue::from(*pp)),
                    ("sd", JsonValue::from(sd)),
                    ("volume_bytes", JsonValue::from(v)),
                ]);
            }
        }
        for (&b, &per) in batches.iter().zip(per_record.iter()) {
            j.row(&[
                ("series", JsonValue::from("batch_allreduce")),
                ("batch", JsonValue::from(b)),
                ("decode_allreduce_record_bytes", JsonValue::from(per)),
            ]);
        }
        j.write(&path)?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Serve `batch` equal-length sequences through one session and return the
/// (count, total bytes) of decode AllReduce records tagged with that batch
/// size. All sequences prefill first and then decode in lockstep, so every
/// decode iteration carries the full batch.
fn decode_allreduce_at_batch(batch: usize) -> anyhow::Result<(usize, usize)> {
    let plan = Deployment::builder().model("8b").tp(2).workload(16, 8).build()?;
    let mut engine = plan.engine()?;
    {
        let mut session = engine.session();
        for id in 0..batch as u64 {
            session.admit(SequenceInput {
                id,
                prompt: vec![0; 16].into(),
                start: 0,
                max_new_tokens: 8,
            })?;
        }
        while !session.is_idle() {
            session.step()?;
        }
    }
    let summary = engine.trace().summary();
    let agg = summary.batch_view(batch, CollectiveKind::AllReduce, Stage::Decode);
    Ok((agg.count, agg.total_message_bytes))
}
