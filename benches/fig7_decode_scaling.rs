//! Figure 7 reproduction: communication volume scaling with decode
//! sequence length (Sd ∈ {128, 256, 512}, Sp = 128) across parallelism
//! strategies and models.
//!
//! Asserts the paper's sub-linear growth factors: ≈1.50× for 128→256 and
//! ≈1.67× for 256→512 (the `(S_p + S_d − 1)` dilution), PP lowest volume,
//! TP growing fastest in absolute terms.

use commsim::analysis::{InferenceShape, ParallelLayout, VolumeModel};
use commsim::model::ModelArch;
use commsim::report::{fmt_bytes, render_table};

fn main() -> anyhow::Result<()> {
    let layouts = [
        ParallelLayout::new(4, 1),
        ParallelLayout::new(2, 2),
        ParallelLayout::new(1, 4),
    ];
    let sds = [128usize, 256, 512];

    let mut rows = Vec::new();
    for arch in ModelArch::paper_models() {
        let vm = VolumeModel::new(arch.clone());
        for layout in layouts {
            let vols: Vec<f64> = sds
                .iter()
                .map(|&sd| vm.volume(layout, InferenceShape::new(128, sd, 2)).total())
                .collect();
            let g1 = vols[1] / vols[0];
            let g2 = vols[2] / vols[1];
            rows.push(vec![
                arch.name.clone(),
                layout.label(),
                fmt_bytes(vols[0]),
                fmt_bytes(vols[1]),
                fmt_bytes(vols[2]),
                format!("{g1:.3}x / {g2:.3}x"),
            ]);
            // Paper: ~1.50x and ~1.67x growth from the (Sp+Sd−1) dilution.
            // PP and TP=4 track the quoted factors tightly; the hybrid
            // layout carries a larger Gather share (∝ Sd, v/t = 64128 at
            // t=2) so its growth sits slightly higher but stays sub-linear.
            if layout.pp == 1 || layout.tp == 1 {
                anyhow::ensure!((g1 - 1.50).abs() < 0.04, "{} {}: g1={g1}", arch.name, layout.label());
                anyhow::ensure!((g2 - 1.67).abs() < 0.04, "{} {}: g2={g2}", arch.name, layout.label());
            } else {
                anyhow::ensure!((1.45..1.75).contains(&g1), "{} {}: g1={g1}", arch.name, layout.label());
                anyhow::ensure!((1.55..1.90).contains(&g2), "{} {}: g2={g2}", arch.name, layout.label());
            }
            anyhow::ensure!(g1 < 2.0 && g2 < 2.0, "sub-linear in the 2x length step");
        }
    }
    print!(
        "{}",
        render_table(
            "Fig. 7 — volume vs decode length (Sp=128, BF16)",
            &["Model", "Layout", "Sd=128", "Sd=256", "Sd=512", "Growth 128→256 / 256→512"],
            &rows,
        )
    );

    // PP stays lowest at every Sd; TP grows fastest absolutely.
    for arch in ModelArch::paper_models() {
        let vm = VolumeModel::new(arch.clone());
        for &sd in &sds {
            let s = InferenceShape::new(128, sd, 2);
            let tp = vm.volume(layouts[0], s).total();
            let hy = vm.volume(layouts[1], s).total();
            let pp = vm.volume(layouts[2], s).total();
            anyhow::ensure!(pp < hy && hy < tp, "{} Sd={sd} ordering", arch.name);
        }
    }
    println!("\nFig. 7 reproduced: sub-linear growth 1.50x/1.67x, PP lowest at every length.");
    Ok(())
}
